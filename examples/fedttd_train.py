"""End-to-end driver — the paper's Fig. 1 distributed-learning workflow.

Multiple "pods" (edge nodes) train local replicas of an LM; every
``--sync-every`` steps they exchange parameter deltas in TT format (the
paper's compression direction) with error feedback, and every pod applies
the average.  Demonstrates, end to end:

  * training substrate: model zoo config, synthetic data pipeline, AdamW,
    grad-accumulated sharded train step,
  * the paper's contribution: TT-compressed parameter exchange
    (core.comm_compress / train.fedttd) with payload accounting,
  * fault tolerance: checkpoint every sync round, then a simulated node
    failure + restart that resumes bit-exact from the manifest.

Run (CPU, ~2 min):
  PYTHONPATH=src python examples/fedttd_train.py
Bigger (~100M params — the full-scale single-host variant):
  PYTHONPATH=src python examples/fedttd_train.py --preset 100m --steps 200
"""

from __future__ import annotations

import argparse
import dataclasses
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.comm_compress import CommCompressionConfig
from repro.data import pipeline as data_pipeline
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.models.registry import build
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train import fedttd
from repro.train.steps import TrainState, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eps", type=float, default=0.08)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.preset == "100m":
        cfg = dataclasses.replace(
            cfg, num_layers=8, d_model=768, num_heads=12, num_kv_heads=12,
            d_ff=3072, vocab_size=32768, head_dim=None)
    model = build(cfg)
    n_params = sum(
        int(np.prod(p.shape))
        for p in jax.tree.leaves(jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"[fedttd] arch={args.arch} preset={args.preset} "
          f"params={n_params/1e6:.1f}M pods={args.pods}")

    mesh = make_host_mesh()
    if hasattr(jax, "set_mesh"):          # newer jax: ambient mesh API
        jax.set_mesh(mesh)
    else:
        mesh.__enter__()                  # 0.4.x: context-manager mesh
    shape = ShapeConfig("fedttd", args.seq, args.batch, "train")
    optimizer = AdamW(learning_rate=cosine_schedule(3e-4, 10, args.steps))
    step_fn = jax.jit(
        make_train_step(model, optimizer, batch_axes=batch_axes(mesh)),
        donate_argnums=(0,))
    comm_cfg = CommCompressionConfig(eps=args.eps, max_rank=32)

    # one independent island per pod: own data shard, own optimizer state
    states, datas = [], []
    for p in range(args.pods):
        params = model.init(jax.random.PRNGKey(args.seed))   # same init
        states.append(TrainState(params=params, opt=optimizer.init(params)))
        datas.append(data_pipeline.for_model(cfg, shape, seed=100 + p))
    fstate = fedttd.init_state([s.params for s in states])

    ckpt_dir = tempfile.mkdtemp(prefix="fedttd_ckpt_")
    ckpt = CheckpointManager(ckpt_dir, keep=2)

    losses = {p: [] for p in range(args.pods)}
    t0 = time.time()
    for step in range(args.steps):
        for p in range(args.pods):
            batch = {k: jnp.asarray(v)
                     for k, v in datas[p].batch_at(step).items()}
            states[p], metrics = step_fn(states[p], batch)
            losses[p].append(float(metrics["loss"]))
        if (step + 1) % args.sync_every == 0:
            synced, fstate = fedttd.sync(
                [s.params for s in states], fstate, comm_cfg)
            states = [s._replace(params=pp)
                      for s, pp in zip(states, synced)]
            ckpt.save(step, states[0])
            print(f"[fedttd] step {step + 1}: synced "
                  f"(payload {fstate.sent_bytes / max(fstate.raw_bytes, 1):.3f}"
                  f"x of dense, losses "
                  + ",".join(f"{losses[p][-1]:.3f}"
                             for p in range(args.pods)) + ")", flush=True)
    wall = time.time() - t0

    # ---- fault tolerance: kill pod 0, restore from checkpoint ------------
    ckpt.wait()
    latest = ckpt.latest_step()
    dead = TrainState(
        params=model.init(jax.random.PRNGKey(99)),     # "rebooted" node
        opt=optimizer.init(model.init(jax.random.PRNGKey(99))))
    restored, manifest = ckpt.restore(dead)
    same = all(
        bool(jnp.allclose(a, b))
        for a, b in zip(jax.tree.leaves(restored.params),
                        jax.tree.leaves(states[0].params)))
    print(f"[fedttd] node-failure drill: restored step {manifest['step']} "
          f"from {ckpt_dir} — params match latest sync: {same}")

    dci = 1 / max(fstate.sent_bytes / max(fstate.raw_bytes, 1), 1e-9)
    print(f"[fedttd] done in {wall:.1f}s: "
          f"loss pod0 {losses[0][0]:.3f} -> {losses[0][-1]:.3f}; "
          f"{fstate.syncs} syncs, DCI payload reduced {dci:.1f}x vs dense")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    assert losses[0][-1] < losses[0][0], "training should reduce loss"


if __name__ == "__main__":
    main()
