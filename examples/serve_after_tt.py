"""Fig. 1, receiving side: reconstruct TT-shipped weights, then serve.

An edge node receives model parameters in TT format (the compressed
payload an aggregator broadcast), reconstructs them (eq. (1)/(2) chained
contractions), and serves batched decode requests with a KV cache —
demonstrating that TTD decoding slots in front of the serving path with
bounded reconstruction error.

Run:  PYTHONPATH=src python examples/serve_after_tt.py
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import CompressionPolicy, TTCompressor
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build


def _pretend_trained(p: jax.Array, alpha: float = 1.0) -> jax.Array:
    """Reshape a ≥2D param's spectrum to s_i ∝ i^-alpha (trained-net-like)."""
    if p.ndim < 2 or p.size < 8192:
        return p
    mat = np.asarray(p, np.float32).reshape(p.shape[0], -1)
    u, s, vt = np.linalg.svd(mat, full_matrices=False)
    target = s[0] * (np.arange(1, s.size + 1.0) ** -alpha)
    out = (u * target) @ vt
    return jnp.asarray(out.reshape(p.shape), p.dtype)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--eps", type=float, default=0.2)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build(cfg)
    mesh = make_host_mesh()
    jax.set_mesh(mesh)
    rng = np.random.default_rng(0)

    # --- sender: compress trained-ish params into the TT payload ----------
    # random init has a flat spectrum (incompressible by design — the
    # policy correctly refuses); impose the power-law spectral decay of
    # trained weights so the demo exercises the TT path.
    params = jax.tree.map(_pretend_trained, model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=args.eps, min_size=8192))
    payload, report = comp.compress(params)
    print(f"[serve] wire payload: {report.total_params:,} -> "
          f"{report.payload_params:,} params ({report.ratio:.2f}x)")

    # --- receiver: reconstruct and serve ----------------------------------
    t0 = time.time()
    params_rx = comp.decompress(payload)
    print(f"[serve] TT decode (eq. 1/2 contractions) in "
          f"{time.time() - t0:.2f}s")
    errs = [
        float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-9))
        for a, b in zip(jax.tree.leaves(params_rx), jax.tree.leaves(params))
    ]
    print(f"[serve] max per-tensor reconstruction rel_err: {max(errs):.4f} "
          f"(ε={args.eps})")

    b = args.batch
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(b, max_len)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    prompts = rng.integers(0, cfg.vocab_size, (b, args.prompt_len), np.int32)

    logits = None
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, cache = decode(params_rx, cache,
                               jnp.asarray(prompts[:, i:i + 1]))
    logits_prompt_tt = logits            # position-aligned comparison point
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    toks = [np.asarray(tok)]
    for _ in range(args.gen - 1):
        logits, cache = decode(params_rx, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        toks.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    gen = np.concatenate(toks, axis=1)
    print(f"[serve] {b} requests × {args.gen} tokens in {dt:.1f}s "
          f"({b * args.gen / dt:.1f} tok/s on CPU)")

    # greedy decode with original vs reconstructed params should mostly agree
    cache2 = model.init_cache(b, max_len)
    logits2 = None
    for i in range(args.prompt_len):
        logits2, cache2 = decode(params, cache2,
                                 jnp.asarray(prompts[:, i:i + 1]))
    agree = float(jnp.mean(
        (jnp.argmax(logits_prompt_tt, -1) == jnp.argmax(logits2, -1)).astype(
            jnp.float32)))
    print(f"[serve] next-token agreement (TT vs dense weights): {agree:.2%}")
    print("[serve] OK")


if __name__ == "__main__":
    main()
