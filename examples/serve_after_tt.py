"""Fig. 1, receiving side — now TT-NATIVE: serve straight from the cores.

An edge node receives model parameters in TT format (the compressed
payload an aggregator broadcast) and serves batched decode requests
WITHOUT reconstructing the dense weights: layer matmuls contract the
activations directly against the TT cores (``models.common.tt_native_params``
→ ``core/tt_linear`` → the fused ``kernels/tt_contract`` chain).  The
original reconstruct-then-serve path (eq. (1)/(2) chained contractions,
then dense matmuls) is kept as the accuracy ORACLE: both paths contract
the same cores in the same order, so their logits must agree to numerical
precision — asserted below, far inside the compression ε bound.

Run:  PYTHONPATH=src python examples/serve_after_tt.py
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    CompressionPolicy, TTCompressor, spectral_decay_pytree, tt_param_bytes,
)
from repro.launch.mesh import make_host_mesh
from repro.models import common as model_common
from repro.models.registry import build


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--eps", type=float, default=0.2)
    args = ap.parse_args()
    with make_host_mesh():          # works on every supported jax version
        _demo(args)


def _demo(args) -> None:
    cfg = get_config(args.arch).reduced()
    model = build(cfg)
    rng = np.random.default_rng(0)

    # --- sender: compress trained-ish params into the TT payload ----------
    # random init has a flat spectrum (incompressible by design — the
    # policy correctly refuses); impose the power-law spectral decay of
    # trained weights so the demo exercises the TT path.
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=args.eps, min_size=8192))
    payload, report = comp.compress(params)
    print(f"[serve] wire payload: {report.total_params:,} -> "
          f"{report.payload_params:,} params ({report.ratio:.2f}x)")

    # --- receiver: TT-native serving params (no dense materialization) ----
    t0 = time.time()
    params_tt = model_common.tt_native_params(payload, family=cfg.family)
    print(f"[serve] TT-native conversion (lead tables only) in "
          f"{time.time() - t0:.2f}s")
    # the oracle still reconstructs (eq. 1/2) — the path TT-native replaces
    t0 = time.time()
    params_rx = comp.decompress(payload)
    print(f"[serve] oracle reconstruct (eq. 1/2 contractions) in "
          f"{time.time() - t0:.2f}s")
    print(f"[serve] resident weight bytes: dense {tt_param_bytes(params_rx):,}"
          f" -> tt-native {tt_param_bytes(params_tt):,}")
    # ε accuracy oracle: compression error vs the ORIGINAL weights must obey
    # the per-tensor TT-SVD guarantee ||W - W_R||_F <= ε||W||_F
    errs = [
        float(jnp.linalg.norm((a - o).astype(jnp.float32))
              / (jnp.linalg.norm(o.astype(jnp.float32)) + 1e-9))
        for a, o in zip(jax.tree.leaves(params_rx), jax.tree.leaves(params))
    ]
    print(f"[serve] max per-tensor reconstruction rel_err: {max(errs):.4f} "
          f"(ε={args.eps})")
    assert max(errs) <= args.eps * 1.05 + 1e-2, (max(errs), args.eps)

    # one decode protocol for every pass: the serving engine's fused driver
    from repro.launch.engine import generate

    b = args.batch
    max_len = args.prompt_len + args.gen
    prompts = rng.integers(0, cfg.vocab_size, (b, args.prompt_len), np.int32)

    run = generate(model, params_tt, prompts, args.gen, max_len=max_len)
    dt = run["prefill_t"] + run["decode_t"]
    print(f"[serve] {b} requests × {args.gen} tokens TT-native in {dt:.1f}s "
          f"({b * args.gen / dt:.1f} tok/s on CPU)")

    # --- oracle: reconstruct-then-serve must match to numerical precision -
    # (gen=1: only the position-aligned post-prompt logits are compared)
    oracle = generate(model, params_rx, prompts, 1, max_len=max_len)
    diff, scale, agree = model_common.logit_parity(
        run["prompt_logits"], oracle["prompt_logits"]
    )
    print(f"[serve] TT-native vs reconstruct oracle: max|Δlogits| {diff:.2e} "
          f"(scale {scale:.2e}), next-token agreement {agree:.2%}")
    # same cores, same contraction order — only rounding differs; this is
    # orders of magnitude tighter than the ε accuracy budget.  (argmax
    # agreement is printed, not asserted: a near-tie can legitimately flip
    # within the rounding tolerance)
    assert diff <= max(0.05 * scale, 1e-3), (diff, scale)

    # greedy decode with the ORIGINAL dense weights should mostly agree —
    # this one is ε-limited (not rounding-limited), so report, don't assert
    orig = generate(model, params, prompts, 1, max_len=max_len)
    _, _, agree_orig = model_common.logit_parity(
        run["prompt_logits"], orig["prompt_logits"]
    )
    print(f"[serve] next-token agreement (TT vs original dense weights): "
          f"{agree_orig:.2%}")
    print("[serve] OK")


if __name__ == "__main__":
    main()
