"""Quickstart — the paper's contribution in five minutes.

1. TT-decompose a weight tensor with the two-phase (HBD + QR) SVD
   (paper Algorithms 1 & 2) and verify the ε-error contract.
2. Compress a whole model-parameter pytree with the TTCompressor policy
   (the Fig. 1 "edge → cloud" payload) and reconstruct it.
3. Compare the paper-faithful unblocked HBD with the MXU-oriented
   blocked-WY variant — identical math, different schedule.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CompressionPolicy,
    TTCompressor,
    svd,
    tt_reconstruct,
    ttd,
)

# --------------------------------------------------------------------------
# 1. TT-SVD of one tensor, ε contract
# --------------------------------------------------------------------------
print("== 1. TT-SVD (Algorithm 1) with two-phase SVD (Algorithm 2)")
rng = np.random.default_rng(0)
# a low-rank-ish 4D tensor (what trained conv kernels look like)
u = rng.standard_normal((64, 8)) @ rng.standard_normal((8, 576))
w = jnp.asarray(u.reshape(64, 64, 3, 3), jnp.float32)

for eps in (0.01, 0.1, 0.3):
    t = ttd(w, eps=eps)                       # dynamic δ-ranks, HBD SVD
    rec = tt_reconstruct(t).reshape(w.shape)
    err = float(jnp.linalg.norm(rec - w) / jnp.linalg.norm(w))
    print(f"  eps={eps:<5} ranks={t.ranks}  ratio={t.compression_ratio:5.1f}x"
          f"  rel_err={err:.4f}  (contract: err <= eps)")
    assert err <= eps + 1e-6

# --------------------------------------------------------------------------
# 2. Whole-model compression (Fig. 1 workflow)
# --------------------------------------------------------------------------
print("\n== 2. Model-level TT compression (TTCompressor)")
params = {
    "embed": jnp.asarray(rng.standard_normal((2048, 256)), jnp.float32),
    "mlp/up": jnp.asarray(
        (rng.standard_normal((256, 16)) @ rng.standard_normal((16, 1024))),
        jnp.float32),
    "norm/scale": jnp.ones((256,), jnp.float32),       # tiny → sent raw
}
comp = TTCompressor(CompressionPolicy(eps=0.15))
payload, report = comp.compress(params)
restored = comp.decompress(payload)
print(f"  total={report.total_params:,} -> payload={report.payload_params:,}"
      f"  ({report.ratio:.2f}x smaller on the wire)")
for name, (kind, before, after) in report.per_param.items():
    print(f"    {name:<12} {kind:<4} {before:>8,} -> {after:>8,}")
err = float(jnp.linalg.norm(restored["mlp/up"] - params["mlp/up"])
            / jnp.linalg.norm(params["mlp/up"]))
print(f"  mlp/up reconstruction rel_err = {err:.4f}")

# --------------------------------------------------------------------------
# 3. Unblocked (paper-faithful) vs blocked-WY (MXU) HBD
# --------------------------------------------------------------------------
print("\n== 3. Two-phase SVD: unblocked vs blocked-WY HBD")
a = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
ref = jnp.linalg.svd(a, compute_uv=False)
for impl in ("unblocked", "blocked"):
    r = svd(a, method="two_phase", hbd_impl=impl)          # compile
    jax.block_until_ready(r.s)
    t0 = time.perf_counter()
    r = svd(a, method="two_phase", hbd_impl=impl)
    jax.block_until_ready(r.s)
    dt = (time.perf_counter() - t0) * 1e3
    serr = float(jnp.max(jnp.abs(r.s[:256] - ref)) / ref[0])
    print(f"  {impl:<10} warm={dt:7.1f}ms   max sigma err={serr:.2e}")
print("\nquickstart OK")
