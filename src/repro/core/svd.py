"""Two-phase SVD (paper §II-A2): Householder bidiagonalization + diagonalization.

The paper's central algorithmic move is splitting SVD into:

  phase 1 (HBD)     A = U_B B V_B^T      — the hardware-accelerated phase
  phase 2 (diag)    B = Q  Σ  P^T         — "standard QR-based procedure",
                                            *unchanged* between their baseline
                                            and TT-Edge (Table III)

and composing      A = (U_B Q) Σ (P^T V_B^T) = U Σ V^T.

Phase 2 here defaults to the library path on the *compact* n×n bidiagonal
block (cheap: B is bidiagonal so this is O(n^2) work for the values plus
O(n^3) for the small basis products — tiny next to phase 1's O(M N^2), the
same asymmetry the paper measures as 3.6:1).  A pure-JAX Golub–Kahan QR
sweep lives in ``bidiag_qr.py`` and is selectable with
``diag_method="golub_kahan"``; tests use it as an independent oracle.

Also implements the paper's ``Sorting_Basis`` (Alg. 1 lines 18-25): sort σ
descending, permute the bases with the recorded index vector.  Hardware uses
bubble sort; any comparison sort yields the identical (σ_s, Ind) pair, so the
JAX path uses ``argsort`` (the Pallas bitonic-network kernel in
``kernels/singular_sort`` is the TPU-idiomatic hardware analogue).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.hbd import householder_bidiagonalize
from repro.core import blocked as _blocked


class SVDResult(NamedTuple):
    u: jax.Array
    s: jax.Array
    vt: jax.Array


def sorting_basis(u: jax.Array, s: jax.Array, vt: jax.Array) -> SVDResult:
    """Paper Sorting_Basis: descending sort of σ + basis permutation.

    Returns (U_s, Σ_s, V_s^T) with the same index vector applied to U's
    columns and V^T's rows (Alg. 1 line 22).
    """
    ind = jnp.argsort(-s)  # descending; the paper's bubble-sort index vector
    return SVDResult(u=u[:, ind], s=s[ind], vt=vt[ind, :])


@functools.partial(jax.jit, static_argnames=("method", "hbd_impl", "panel"))
def svd(
    a: jax.Array,
    method: str = "two_phase",
    hbd_impl: str = "unblocked",
    panel: int = 32,
) -> SVDResult:
    """SVD with selectable factorization path.

    method:
      "two_phase" — the paper's HBD + diagonalization split (default).
      "library"   — jnp.linalg.svd reference (the 'cloud' path in Fig. 1).
    hbd_impl:
      "unblocked" — paper-faithful Algorithm 2 (one reflector at a time).
      "blocked"   — WY/compact-blocked variant (MXU-friendly; beyond-paper).
    Always returns thin, descending-sorted factors: u (M,K), s (K,), vt (K,N)
    with K = min(M, N).
    """
    m, n = a.shape
    if method == "library":
        u, s, vt = jnp.linalg.svd(a, full_matrices=False)
        return sorting_basis(u, s, vt)
    if method != "two_phase":
        raise ValueError(f"unknown svd method: {method}")

    if m < n:
        # HBD expects tall matrices; SVD(A) = SVD(A^T) with factors swapped.
        r = svd(a.T, method=method, hbd_impl=hbd_impl, panel=panel)
        return SVDResult(u=r.vt.T, s=r.s, vt=r.u.T)

    orig = a.dtype
    a32 = a.astype(jnp.float32)
    if hbd_impl == "blocked":
        u_b, b, v_bt = _blocked.blocked_bidiagonalize(a32, panel=panel)
    elif hbd_impl == "unblocked":
        u_b, b, v_bt = householder_bidiagonalize(a32)
    else:
        raise ValueError(f"unknown hbd_impl: {hbd_impl}")

    # Phase 2 on the compact n×n bidiagonal block.
    bn = b[:n, :n]
    q, s, pt = jnp.linalg.svd(bn, full_matrices=False)
    u = u_b[:, :n] @ q
    vt = pt @ v_bt
    res = sorting_basis(u, s, vt)
    return SVDResult(
        u=res.u.astype(orig), s=res.s.astype(orig), vt=res.vt.astype(orig)
    )


@functools.partial(jax.jit, static_argnames=("method", "hbd_impl", "panel"))
def svd_batched(
    a: jax.Array,
    method: str = "two_phase",
    hbd_impl: str = "unblocked",
    panel: int = 32,
) -> SVDResult:
    """Batched SVD of a (B, M, N) stack — one launch, B factorizations.

    vmaps the selected factorization path (two-phase HBD included), so a
    bucket of same-shape unfoldings costs a single dispatch instead of B.
    Member k of the result equals ``svd(a[k], ...)`` exactly.
    """
    if a.ndim != 3:
        raise ValueError(f"svd_batched expects (B, M, N), got {a.shape}")
    fn = functools.partial(svd, method=method, hbd_impl=hbd_impl, panel=panel)
    return jax.vmap(fn)(a)


def svd_reconstruct(r: SVDResult) -> jax.Array:
    return (r.u * r.s[None, :]) @ r.vt
