"""TTCompressor — the public model-compression API (paper Fig. 1 workflow).

Compresses a pytree of model parameters into TT format for transmission
(the "edge → cloud" direction) and reconstructs on arrival.  This is the
framework-level face of the paper's contribution: a compression policy
decides, per parameter, whether/how to tensorize, and the TT-SVD engine
(two-phase HBD SVD) does the factorization.

Policy defaults follow DESIGN.md §5:
  * params with fewer than ``min_size`` elements are sent raw (routers,
    norms, biases — TT overhead would exceed the payload);
  * matrices/embeddings are re-tensorized with balanced factors
    (TT-Rec-style) to depth >= ``min_dims``;
  * conv kernels (4D) keep their natural dims;
  * a parameter is only kept in TT form if it actually compresses
    (ratio > 1), otherwise raw — same accept/reject the paper's δ-rule
    effectively applies.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tt as _tt


@dataclass
class CompressionPolicy:
    eps: float = 0.05
    min_size: int = 4096            # below this, send raw
    max_factor: int = 64            # balanced tensorization factor cap
    min_dims: int = 3               # tensorize to at least this many dims
    max_rank: Optional[int] = None
    svd_method: str = "two_phase"
    hbd_impl: str = "unblocked"


@dataclass
class CompressedParam:
    kind: str                        # "tt" | "raw"
    tt: Optional[_tt.TTTensor]
    raw: Optional[jax.Array]
    orig_shape: Tuple[int, ...]
    orig_dtype: Any

    @property
    def payload_params(self) -> int:
        if self.kind == "tt":
            return self.tt.num_params
        return int(np.prod(self.orig_shape))


@dataclass
class CompressionReport:
    total_params: int
    payload_params: int
    per_param: Dict[str, Tuple[str, int, int]] = field(default_factory=dict)

    @property
    def ratio(self) -> float:
        return self.total_params / max(self.payload_params, 1)


def _tensorize_dims(shape: Tuple[int, ...], policy: CompressionPolicy):
    if len(shape) >= policy.min_dims:
        return list(shape)
    dims = _tt.tensorize_shape(shape, policy.max_factor)
    if len(dims) < policy.min_dims:
        dims = _tt.tensorize_shape(shape, max(8, policy.max_factor // 8))
    return dims


def compress_param(x: jax.Array, policy: CompressionPolicy) -> CompressedParam:
    shape = tuple(x.shape)
    size = int(np.prod(shape))
    if size < policy.min_size or min(shape or (1,)) == 0:
        return CompressedParam("raw", None, x, shape, x.dtype)
    dims = _tensorize_dims(shape, policy)
    if len(dims) < 2:
        return CompressedParam("raw", None, x, shape, x.dtype)
    tt = _tt.ttd(
        x,
        eps=policy.eps,
        dims=dims,
        svd_method=policy.svd_method,
        hbd_impl=policy.hbd_impl,
        max_rank=policy.max_rank,
    )
    if tt.num_params >= size:                     # reject non-compressions
        return CompressedParam("raw", None, x, shape, x.dtype)
    return CompressedParam("tt", tt, None, shape, x.dtype)


def decompress_param(c: CompressedParam) -> jax.Array:
    if c.kind == "raw":
        return c.raw
    w = _tt.tt_reconstruct(c.tt)
    return w.reshape(c.orig_shape).astype(c.orig_dtype)


class TTCompressor:
    """Compress/decompress pytrees of parameters for transmission."""

    def __init__(self, policy: Optional[CompressionPolicy] = None):
        self.policy = policy or CompressionPolicy()

    def compress(self, params) -> Tuple[Any, CompressionReport]:
        leaves, treedef = jax.tree.flatten(params)
        paths = [
            "/".join(str(k) for k in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(params)[0]
        ]
        out = []
        report = CompressionReport(total_params=0, payload_params=0)
        for name, leaf in zip(paths, leaves):
            c = compress_param(jnp.asarray(leaf), self.policy)
            out.append(c)
            size = int(np.prod(c.orig_shape))
            report.total_params += size
            report.payload_params += c.payload_params
            report.per_param[name] = (c.kind, size, c.payload_params)
        return jax.tree.unflatten(treedef, out), report

    def decompress(self, compressed) -> Any:
        return jax.tree.map(
            decompress_param,
            compressed,
            is_leaf=lambda x: isinstance(x, CompressedParam),
        )
