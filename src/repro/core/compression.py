"""TTCompressor — the public model-compression API (paper Fig. 1 workflow).

Compresses a pytree of model parameters into TT format for transmission
(the "edge → cloud" direction) and reconstructs on arrival.  This is the
framework-level face of the paper's contribution: a compression policy
decides, per parameter, whether/how to tensorize, and the TT-SVD engine
(two-phase HBD SVD) does the factorization.

Policy defaults follow DESIGN.md §5:
  * params with fewer than ``min_size`` elements are sent raw (routers,
    norms, biases — TT overhead would exceed the payload);
  * matrices/embeddings are re-tensorized with balanced factors
    (TT-Rec-style) to depth >= ``min_dims``;
  * conv kernels (4D) keep their natural dims;
  * a parameter is only kept in TT form if it actually compresses
    (ratio > 1), otherwise raw — same accept/reject the paper's δ-rule
    effectively applies.

Execution plans
---------------
``plan="batched"`` (default) routes compression through the planning pass
(``core/plan.py``): parameters are bucketed by (padded) tensorized shape
and each bucket is decomposed by ONE batched TT-SVD launch
(``core/batch_exec.py``), optionally sharded over a ``launch/mesh.py``
device mesh.  ``plan="serial"`` is the original per-parameter loop — kept
as the escape hatch and as the equivalence oracle the batched path is
tested against: same ε guarantee, and for exact-shape bucket members the
same accept/reject decision and live ranks.  The one intentional
divergence is *padded* members (shapes merged into a larger bucket under
``pad_tolerance``): their cores carry the padded mode dims, so payload
accounting is up to ``pad_tolerance`` larger than serial and the ratio>1
accept/reject is correspondingly more conservative — a padded member near
the break-even point may be sent raw where serial would keep TT.  Set
``pad_tolerance=0`` to disable padding merges and recover strict parity.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tt as _tt
from repro.core import plan as _plan
from repro.core import batch_exec as _exec


@dataclass
class CompressionPolicy:
    eps: float = 0.05
    min_size: int = 4096            # below this, send raw
    max_factor: int = 64            # balanced tensorization factor cap
    min_dims: int = 3               # tensorize to at least this many dims
    max_rank: Optional[int] = None
    svd_method: str = "two_phase"
    hbd_impl: str = "unblocked"
    plan: str = "batched"           # "batched" | "serial" execution plan
    pad_tolerance: float = 0.25     # max element overhead to join a bucket
    serial_cutoff_elems: int = 1 << 24   # padded-work bound for batching


@dataclass
class CompressedParam:
    kind: str                        # "tt" | "raw"
    tt: Optional[_tt.TTTensor]
    raw: Optional[jax.Array]
    orig_shape: Tuple[int, ...]
    orig_dtype: Any
    # set when the param was zero-padded into a larger bucket: the pre-pad
    # tensorized dims the reconstruction must be cropped back to
    crop_dims: Optional[Tuple[int, ...]] = None

    @property
    def payload_params(self) -> int:
        if self.kind == "tt":
            return self.tt.num_params
        return int(np.prod(self.orig_shape))


@dataclass
class CompressionReport:
    total_params: int
    payload_params: int
    per_param: Dict[str, Tuple[str, int, int]] = field(default_factory=dict)
    plan_fingerprint: Optional[str] = None
    exec_stats: Optional[_exec.ExecStats] = None

    @property
    def ratio(self) -> float:
        return self.total_params / max(self.payload_params, 1)


# single source of truth for raw/TT dim routing, shared with the planner
_tensorize_dims = _plan.tensorize_dims


def compress_param(x: jax.Array, policy: CompressionPolicy) -> CompressedParam:
    shape = tuple(x.shape)
    size = int(np.prod(shape))
    if size < policy.min_size or min(shape or (1,)) == 0:
        return CompressedParam("raw", None, x, shape, x.dtype)
    dims = _tensorize_dims(shape, policy)
    if len(dims) < 2:
        return CompressedParam("raw", None, x, shape, x.dtype)
    tt = _tt.ttd(
        x,
        eps=policy.eps,
        dims=dims,
        svd_method=policy.svd_method,
        hbd_impl=policy.hbd_impl,
        max_rank=policy.max_rank,
    )
    if tt.num_params >= size:                     # reject non-compressions
        return CompressedParam("raw", None, x, shape, x.dtype)
    return CompressedParam("tt", tt, None, shape, x.dtype)


def decompress_param(c: CompressedParam) -> jax.Array:
    if c.kind == "raw":
        return c.raw
    w = _tt.tt_reconstruct(c.tt)
    if c.crop_dims is not None and tuple(c.crop_dims) != tuple(c.tt.shape):
        w = w[tuple(slice(0, d) for d in c.crop_dims)]
    return w.reshape(c.orig_shape).astype(c.orig_dtype)


def _default_mesh():
    try:
        from repro.launch.sharding import current_mesh
        return current_mesh()
    except Exception:                              # launch layer unavailable
        return None


class TTCompressor:
    """Compress/decompress pytrees of parameters for transmission.

    mesh: optional ``launch/mesh.py`` mesh the batched executor shards
    bucket batches over (round-robin on the ``data`` axis); defaults to the
    mesh registered with ``launch.sharding.set_mesh_axis_sizes``, if any.
    """

    def __init__(self, policy: Optional[CompressionPolicy] = None, mesh=None):
        self.policy = policy or CompressionPolicy()
        self.mesh = mesh

    def compress(self, params, plan: Optional[str] = None
                 ) -> Tuple[Any, CompressionReport]:
        mode = plan or self.policy.plan
        if mode == "serial":
            return self._compress_serial(params)
        if mode != "batched":
            raise ValueError(f"unknown compression plan: {mode!r}")
        return self._compress_batched(params)

    # ---- the original per-param loop: fallback + equivalence oracle ----
    def _compress_serial(self, params) -> Tuple[Any, CompressionReport]:
        leaves, treedef = jax.tree.flatten(params)
        paths = [
            "/".join(str(k) for k in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(params)[0]
        ]
        out = []
        report = CompressionReport(total_params=0, payload_params=0)
        for name, leaf in zip(paths, leaves):
            c = compress_param(jnp.asarray(leaf), self.policy)
            out.append(c)
            size = int(np.prod(c.orig_shape))
            report.total_params += size
            report.payload_params += c.payload_params
            report.per_param[name] = (c.kind, size, c.payload_params)
        return jax.tree.unflatten(treedef, out), report

    # ---- the batched planner/executor path ----
    def _compress_batched(self, params) -> Tuple[Any, CompressionReport]:
        leaves, treedef = jax.tree.flatten(params)
        paths = [
            "/".join(str(k) for k in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(params)[0]
        ]
        cplan = _plan.build_plan(
            params, self.policy,
            pad_tolerance=self.policy.pad_tolerance,
            serial_cutoff_elems=self.policy.serial_cutoff_elems,
        )
        executor = _exec.BucketExecutor(mesh=self.mesh or _default_mesh())
        results = executor.run(cplan, leaves, self.policy)

        out = [None] * len(leaves)
        for e in cplan.raw:
            x = jnp.asarray(leaves[e.index])
            out[e.index] = CompressedParam("raw", None, x, e.shape, x.dtype)
        for idx, (tt, pre_pad_dims) in results.items():
            x = jnp.asarray(leaves[idx])
            shape = tuple(x.shape)
            size = int(np.prod(shape))
            if tt.num_params >= size:             # reject non-compressions
                out[idx] = CompressedParam("raw", None, x, shape, x.dtype)
            else:
                crop = (tuple(pre_pad_dims)
                        if tuple(pre_pad_dims) != tuple(tt.shape) else None)
                out[idx] = CompressedParam(
                    "tt", tt, None, shape, x.dtype, crop_dims=crop
                )

        report = CompressionReport(
            total_params=0, payload_params=0,
            plan_fingerprint=cplan.fingerprint,
            exec_stats=executor.stats,
        )
        for name, c in zip(paths, out):
            size = int(np.prod(c.orig_shape))
            report.total_params += size
            report.payload_params += c.payload_params
            report.per_param[name] = (c.kind, size, c.payload_params)
        return jax.tree.unflatten(treedef, out), report

    def decompress(self, compressed) -> Any:
        return jax.tree.map(
            decompress_param,
            compressed,
            is_leaf=lambda x: isinstance(x, CompressedParam),
        )
