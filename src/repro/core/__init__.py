"""repro.core — the paper's contribution: TT decomposition via two-phase SVD.

Public surface:
  hbd.householder_bidiagonalize   — paper Algorithm 2 (phase 1)
  bidiag_qr.bidiag_svd_values     — phase 2 oracle (Golub–Kahan QR)
  svd.svd                          — two-phase SVD (+ sorting_basis)
  truncation.*                     — δ-truncation (Alg. 1 lines 27-31)
  tt.ttd / tt.ttd_static           — Algorithm 1 (offline / in-graph)
  tt.tt_reconstruct                — eq. (1)/(2) decoding
  baselines.tucker_hosvd / tr_svd  — Table-I comparison methods
  compression.TTCompressor         — pytree-level model compression API
  tt_linear.TTLinear / tt_apply    — TT-native serving: apply dense layers
                                     straight from cores (no reconstruction)
  comm_compress.*                  — FedTTD cross-pod TT-compressed sync
  blocked.*                        — WY-blocked HBD (beyond-paper, MXU form)
  plan.build_plan                  — batched-compression planning pass
  batch_exec.BucketExecutor        — one batched TT-SVD launch per bucket
  *_batched                        — vmapped/batch-grid variants of the SVD
                                     substrate (one launch, B problems)
"""

from repro.core.hbd import (
    householder_bidiagonalize,
    householder_bidiagonalize_batched,
    house,
    house_mm_update,
)
from repro.core.svd import (
    svd, svd_batched, sorting_basis, svd_reconstruct, SVDResult,
)
from repro.core.truncation import (
    delta_threshold,
    truncation_rank,
    truncation_rank_static,
    truncate_masked,
    tail_norms,
)
from repro.core.tt import (
    TTTensor,
    StaticTT,
    ttd,
    ttd_static,
    ttd_static_batched,
    tt_reconstruct,
    static_tt_reconstruct,
    static_tt_member,
    static_tt_crop,
    tensorize_shape,
    auto_factorize,
    tt_max_ranks,
)
from repro.core.plan import (
    Bucket,
    CompressionPlan,
    PlanEntry,
    build_plan,
)
from repro.core.batch_exec import BucketExecutor, ExecStats, round_robin_chunks
from repro.core.compression import (
    CompressionPolicy,
    TTCompressor,
    compress_param,
    decompress_param,
)
from repro.core.tt_linear import (
    TTLinear,
    dequantize_array,
    dequantize_tt,
    is_tt_linear,
    quant_dtype,
    quantize_array,
    quantize_tt,
    quantize_tt_tree,
    select_layer,
    spectral_decay_pytree,
    tt_apply,
    tt_apply_experts,
    tt_leaf_bytes,
    tt_linear_from_tt,
    tt_param_bytes,
)
from repro.core.comm_compress import (
    CommCompressionConfig,
    compress_delta_batched,
    pod_sync_tt,
    pod_sync_dense,
    fedttd_roundtrip,
)
