"""Householder bidiagonalization (paper Algorithm 2), in pure JAX.

This is the paper-faithful implementation of the HBD-ACC datapath:

  * ``house``            — the HOUSE function (eqs. (3)/(5)): given x, produce
                           the Householder vector v and the resulting pivot
                           value q = -sign(x_1) * ||x||.
  * ``house_mm_update``  — the HOUSE_MM_UPDATE procedure: apply the reflector
                           to a trailing submatrix as *two GEMMs* plus a
                           vector-by-scalar division, exactly as the paper
                           formulates it for GEMM-accelerator reuse:
                               beta  = v[0] * q
                               vec1  = v / beta        (order == 0)
                               vec2  = v^T @ SubArray
                               SubArray += vec1 @ vec2
  * ``householder_bidiagonalize`` — the full Algorithm-2 loop
                           (Householder *reduction* followed by Householder
                           *accumulation* of U_B and V_B^T), expressed with
                           ``jax.lax.fori_loop`` and static-shape masking so
                           that it JIT-compiles for any (M, N).

Faithfulness notes
------------------
The paper operates on sub-views ``A[i:M, i:N]`` with shrinking shapes; XLA
requires static shapes, so we implement the identical arithmetic with
*masking*: at step i every vector is full-length with entries < i forced to
zero.  A masked Householder vector produces a reflector that acts as the
identity on the masked prefix, which is exactly the "embed the (M-i)×(M-i)
reflector into the lower-right corner of an M×M identity" construction used
in LAPACK/ScaLAPACK — the arithmetic matches the paper's element-for-element.

The blocked (WY) variant used for MXU efficiency lives in
``repro/core/blocked.py``; THIS file is the recorded paper baseline.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class HouseResult(NamedTuple):
    q: jax.Array  # the pivot value: -sign(x1) * ||x||
    v: jax.Array  # the (masked, unnormalized) Householder vector


def _sign(x: jax.Array) -> jax.Array:
    """sign(x) with sign(0) := 1 (LAPACK convention; avoids zero reflectors)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def house(x: jax.Array, mask: jax.Array) -> HouseResult:
    """Paper HOUSE (Alg. 2 lines 22-26) on a masked full-length vector.

    x    : (L,) the column/row to reduce; entries where ``mask`` is False are
           ignored (they correspond to the A[:i] prefix the paper never sees).
    mask : (L,) bool, True on the active suffix. mask[i0] marks x_1.

    Returns q = -sign(x1)*||x|| and v with v[i0] = x1 + sign(x1)*||x||.
    """
    x = jnp.where(mask, x, 0.0)
    norm = jnp.linalg.norm(x)
    # x1 = first *active* element. mask is a suffix mask, so argmax finds it.
    i0 = jnp.argmax(mask)
    x1 = x[i0]
    s = _sign(x1)
    q = -s * norm
    v = x.at[i0].add(s * norm)
    v = jnp.where(mask, v, 0.0)
    return HouseResult(q=q, v=v)


def house_mm_update(
    q: jax.Array,
    v: jax.Array,
    sub: jax.Array,
    order: int | jax.Array,
    row_mask: jax.Array,
    col_mask: jax.Array,
) -> jax.Array:
    """Paper HOUSE_MM_UPDATE (Alg. 2 lines 27-32) with static shapes.

    order == 0: left transform,   sub += (v/beta) @ (v^T sub)
    order == 1: right transform,  sub += (sub v^T... ) — the paper writes the
                symmetric form: vec1 = sub @ v (row-space), vec2 = v/beta.

    beta = v[first_active] * q.  For a Householder vector built by HOUSE,
    v^T v = 2 * v1 * (v1 - x1 + x1) ... = -2 * v1 * q, hence
    I - 2 v v^T / (v^T v) = I + v v^T / (v1 q) = I + (v/beta) v^T.
    The update is numerically identical to applying the reflector H.

    row_mask/col_mask confine the update to the active trailing block, which
    is mathematically a no-op (v is already masked) but keeps the untouched
    region bit-exact with the paper's sub-view semantics.
    """
    left = _is_left_static(order)
    v = jnp.where(row_mask if left else col_mask, v, 0.0)
    i0 = jnp.argmax(row_mask) if left else jnp.argmax(col_mask)
    beta = v[i0] * q

    # Guard: if the active column is already zero, beta == 0 and H == I.
    safe = jnp.abs(beta) > 0
    inv_beta = jnp.where(safe, 1.0 / jnp.where(safe, beta, 1.0), 0.0)

    if _is_left_static(order):
        vec1 = v * inv_beta                      # (M,)   — VEC DIVISION stage
        vec2 = v @ sub                           # (N,)   — GEMM #1
        upd = jnp.outer(vec1, vec2)              # (M, N) — GEMM #2 (rank-1)
    else:
        vec1 = sub @ v                           # (M,)   — GEMM #1
        vec2 = v * inv_beta                      # (N,)   — VEC DIVISION stage
        upd = jnp.outer(vec1, vec2)              # (M, N) — GEMM #2 (rank-1)
    return sub + upd


def _is_left_static(order) -> bool:
    if isinstance(order, (int, bool)):
        return int(order) == 0
    raise TypeError("order must be a static python int (0=left, 1=right)")


@functools.partial(jax.jit, static_argnames=("compute_uv",))
def householder_bidiagonalize(
    a: jax.Array, compute_uv: bool = True
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Paper Algorithm 2: A (M×N, M>=N) -> (U_B, B, V_B^T), A = U_B B V_B^T.

    B is upper-bidiagonal (returned as a dense M×N matrix whose only nonzeros
    are B[i,i] and B[i,i+1] — the dense form is what downstream phase-2
    diagonalization consumes).

    Implements both loops of Algorithm 2:
      * reduction   (i = 1..N): HOUSE + HOUSE_MM_UPDATE on A, storing the
        Householder vectors *in place* in A's zeroed-out wings — the
        software analogue of the paper's "on-chip retention of Householder
        vectors" (nothing is written back to a separate buffer).
      * accumulation(i = N..1): HOUSE_MM_UPDATE on U_B and V_B^T using the
        retained vectors.
    """
    m, n = a.shape
    if m < n:
        raise ValueError(f"HBD expects M >= N, got {a.shape}; transpose first")
    orig_dtype = a.dtype
    a = a.astype(jnp.float32)

    rows = jnp.arange(m)
    cols = jnp.arange(n)

    def reduction_step(i, carry):
        a_, diag, super_ = carry
        row_mask = rows >= i          # active rows  i..M
        col_mask = cols >= i + 1      # active cols  i+1..N (for the right xform)

        # ---- left transform: eliminate sub-diagonal of column i ----
        x = a_[:, i]
        q, v_l = house(x, row_mask)
        diag = diag.at[i].set(q)                      # B[i, i]
        sub = jnp.where(row_mask[:, None] & col_mask[None, :], a_, 0.0)
        sub = house_mm_update(q, v_l, sub, 0, row_mask, col_mask)
        a_ = jnp.where(row_mask[:, None] & col_mask[None, :], sub, a_)
        # retain v_L in the reduced column (paper line 7: A[i,i] <- v_L[1])
        a_ = a_.at[:, i].set(jnp.where(row_mask, v_l, a_[:, i]))

        # ---- right transform: eliminate row i beyond the superdiagonal ----
        def right(a_, super_):
            y = a_[i, :]
            qr_, v_r = house(y, col_mask)
            super_ = super_.at[i].set(qr_)            # B[i, i+1]
            rmask2 = rows >= i + 1
            sub2 = jnp.where(rmask2[:, None] & col_mask[None, :], a_, 0.0)
            sub2 = house_mm_update(qr_, v_r, sub2, 1, rmask2, col_mask)
            a_ = jnp.where(rmask2[:, None] & col_mask[None, :], sub2, a_)
            a_ = a_.at[i, :].set(jnp.where(col_mask, v_r, a_[i, :]))
            return a_, super_

        def no_right(a_, super_):
            return a_, super_

        a_, super_ = jax.lax.cond(i < n - 1, right, no_right, a_, super_)
        return a_, diag, super_

    diag0 = jnp.zeros((n,), jnp.float32)
    super0 = jnp.zeros((n,), jnp.float32)
    a_red, diag, super_ = jax.lax.fori_loop(
        0, n, reduction_step, (a, diag0, super0)
    )

    # Dense bidiagonal B (M×N): diag + superdiagonal.
    b = jnp.zeros((m, n), jnp.float32)
    b = b.at[cols, cols].set(diag)
    b = b.at[cols[:-1], cols[:-1] + 1].set(super_[:-1])

    if not compute_uv:
        eye_small = jnp.zeros((0, 0), orig_dtype)
        return eye_small, b.astype(orig_dtype), eye_small

    # ---- accumulation loop (Alg. 2 lines 14-18), i = N..1 ----
    u_b0 = jnp.eye(m, dtype=jnp.float32)
    v_bt0 = jnp.eye(n, dtype=jnp.float32)

    def accumulation_step(k, carry):
        i = n - 1 - k                     # i walks N-1 .. 0
        u_b, v_bt = carry
        row_mask = rows >= i
        col_mask = cols >= i + 1

        v_l = jnp.where(row_mask, a_red[:, i], 0.0)
        q_l = diag[i]
        # update ALL columns of U_B in the active row block (the paper's
        # U_B[i:M, :] — using i+1: for columns loses the i-th column's mix).
        ucols = jnp.arange(m) >= i
        usub = jnp.where(row_mask[:, None] & ucols[None, :], u_b, 0.0)
        usub = house_mm_update(q_l, v_l, usub, 0, row_mask, ucols)
        u_b = jnp.where(row_mask[:, None] & ucols[None, :], usub, u_b)

        def acc_right(v_bt):
            # Backward accumulation, paper order-1 form: V_B^T <- V_B^T @ H_i^R
            # (vec1 = SubArray @ v, vec2 = v/beta, SubArray += vec1 (x) vec2).
            # Accumulating right-multiplications for i = N..1 yields
            # H_N ... H_1 = V_B^T.  Rows 0..i of V_B^T are still e_j^T at this
            # point (identity block), for which the update is a no-op, so we
            # confine it to the active i+1.. row block.
            v_r = jnp.where(col_mask, a_red[i, :], 0.0)
            q_r = super_[i]
            vsub = jnp.where(col_mask[:, None], v_bt, 0.0)
            vsub = house_mm_update(q_r, v_r, vsub, 1, col_mask, col_mask)
            return jnp.where(col_mask[:, None], vsub, v_bt)

        v_bt = jax.lax.cond(i < n - 1, acc_right, lambda v: v, v_bt)
        return u_b, v_bt

    u_b, v_bt = jax.lax.fori_loop(0, n, accumulation_step, (u_b0, v_bt0))
    return (
        u_b.astype(orig_dtype),
        b.astype(orig_dtype),
        v_bt.astype(orig_dtype),
    )


@functools.partial(jax.jit, static_argnames=("compute_uv",))
def householder_bidiagonalize_batched(
    a: jax.Array, compute_uv: bool = True
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched Algorithm 2 over a (B, M, N) stack: one launch, B HBDs.

    Everything in the unblocked loop is masking arithmetic + fori_loop, so
    ``jax.vmap`` lifts it wholesale; member k equals
    ``householder_bidiagonalize(a[k])`` exactly.  This is the vmap'd entry
    the batched TT-SVD planner feeds whole same-shape buckets through.
    """
    if a.ndim != 3:
        raise ValueError(f"expected (B, M, N), got {a.shape}")
    fn = functools.partial(householder_bidiagonalize, compute_uv=compute_uv)
    return jax.vmap(fn)(a)


def bidiagonal_bands(b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Extract (diag, superdiag) bands from a dense M×N upper-bidiagonal B."""
    n = b.shape[1]
    idx = jnp.arange(n)
    d = b[idx, idx]
    e = b[idx[:-1], idx[:-1] + 1]
    return d, e
