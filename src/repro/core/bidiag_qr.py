"""Library-free diagonalization oracle for phase 2 of the two-phase SVD.

The paper's phase 2 is a "standard QR-based procedure" on the bidiagonal B
(unaccelerated in TT-Edge — Table III shows identical baseline/TT-Edge time).
For an independent, LAPACK-free oracle we implement **one-sided Jacobi SVD**
rather than a serial Golub–Kahan bulge chase: Jacobi is quadratically
convergent, has no deflation bookkeeping (so it JITs as a fixed sweep
schedule), and its batched column rotations are the vector-unit-friendly
formulation on TPU — the same serial-hardware-idiom → vector-idiom
translation we apply to the paper's bubble sort (DESIGN.md §2).

``bidiag_svd_values(d, e)`` keeps the bidiagonal-band interface used by
tests: it densifies the (tiny, n×n) bidiagonal block and runs Jacobi.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("sweeps",))
def jacobi_svd_values(a: jax.Array, sweeps: int = 15) -> jax.Array:
    """Singular values of a (M, N) matrix, M >= N, by one-sided Jacobi.

    Each rotation orthogonalizes one column pair of A; at convergence the
    column norms are the singular values.  Fixed sweep schedule (static
    round-robin pair order) so the whole routine is one compiled loop.
    """
    m, n = a.shape
    if m < n:
        return jacobi_svd_values(a.T, sweeps=sweeps)
    a = a.astype(jnp.float32)
    pairs = np.array([(i, j) for i in range(n) for j in range(i + 1, n)],
                     dtype=np.int32)
    if len(pairs) == 0:
        return jnp.abs(jnp.linalg.norm(a, axis=0))
    pairs = jnp.asarray(pairs)

    def rotate(a, pair):
        i, j = pair[0], pair[1]
        ci, cj = a[:, i], a[:, j]
        aii = ci @ ci
        ajj = cj @ cj
        aij = ci @ cj
        # Jacobi rotation that zeroes the (i,j) Gram entry
        small = jnp.abs(aij) <= 1e-30 * jnp.sqrt(aii * ajj + 1e-38)
        tau = (ajj - aii) / jnp.where(small, 1.0, 2.0 * aij)
        t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        t = jnp.where(small, 0.0, t)
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        s = c * t
        new_i = c * ci - s * cj
        new_j = s * ci + c * cj
        a = a.at[:, i].set(new_i)
        a = a.at[:, j].set(new_j)
        return a, None

    def sweep(_, a):
        a, _ = jax.lax.scan(rotate, a, pairs)
        return a

    a = jax.lax.fori_loop(0, sweeps, sweep, a)
    s = jnp.linalg.norm(a, axis=0)
    return jnp.sort(s)[::-1]


def bidiag_svd_values(d: jax.Array, e: jax.Array, sweeps: int = 15) -> jax.Array:
    """Singular values (descending) of the upper-bidiagonal matrix with
    diagonal ``d`` (n,) and superdiagonal ``e`` (n-1,)."""
    n = d.shape[0]
    b = jnp.zeros((n, n), jnp.float32)
    idx = jnp.arange(n)
    b = b.at[idx, idx].set(d.astype(jnp.float32))
    b = b.at[idx[:-1], idx[:-1] + 1].set(e.astype(jnp.float32))
    return jacobi_svd_values(b, sweeps=sweeps)
