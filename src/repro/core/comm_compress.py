"""Cross-pod TT-compressed parameter/gradient synchronization (FedTTD).

Paper Fig. 1, transplanted to the production mesh: within a pod, gradients
are reduced over fast ICI as usual; ACROSS pods — the slow "edge↔edge /
edge↔cloud" link in the paper's setting — parameters are exchanged in TT
format and reconstructed on arrival.

In-graph mechanics (all jittable, shape-static):

  1. ``psum`` the gradient within the pod's (data, model) axes (unchanged).
  2. Every ``sync_every`` steps, each pod TT-compresses the *parameter
     delta* since the last sync (error-feedback residual accumulation keeps
     the compression unbiased over time).
  3. The padded TT cores — a few percent of the raw payload — are
     ``all_gather``-ed over the ``pod`` axis (this is the collective whose
     operand bytes shrink; visible in the dry-run HLO).
  4. Each pod reconstructs the peers' deltas and averages.

This module provides both the shard_map collective path and a pure
single-process simulator used by tests (``fedttd_roundtrip``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import tt as _tt


@dataclass(frozen=True)
class CommCompressionConfig:
    enabled: bool = False
    eps: float = 0.02
    max_rank: int = 32
    min_size: int = 65536           # only compress big tensors' cross-pod sync
    svd_method: str = "library"     # in-graph default; "two_phase" selectable
    sync_every: int = 1


def _flat2d(x: jax.Array) -> jax.Array:
    """Canonical 2D view for in-graph TT of an arbitrary-rank parameter."""
    n = x.size
    rows = int(np.floor(np.sqrt(n)))
    while n % rows != 0:
        rows -= 1
    return x.reshape(rows, n // rows)


def compress_delta(
    delta: jax.Array, cfg: CommCompressionConfig
) -> Tuple[_tt.StaticTT, jax.Array]:
    """TT-compress one tensor in-graph; returns (tt, residual).

    residual = delta - reconstruct(tt): fed back into the error-feedback
    accumulator so repeated syncs converge to the uncompressed average.
    """
    dims = _tt.tensorize_shape(_flat2d(delta).shape, max_factor=64)
    x = delta.astype(jnp.float32).reshape(tuple(dims))
    tt = _tt.ttd_static(
        x, eps=cfg.eps, max_rank=cfg.max_rank, svd_method=cfg.svd_method
    )
    rec = _tt.static_tt_reconstruct(tt).reshape(delta.shape)
    return tt, delta - rec.astype(delta.dtype)


def compress_delta_batched(
    deltas: jax.Array, cfg: CommCompressionConfig
) -> Tuple[_tt.StaticTT, jax.Array]:
    """TT-compress a (P, *shape) stack of same-shape deltas in ONE launch.

    The per-pod serial loop in ``fedttd_roundtrip``/``train.fedttd`` pays a
    dispatch per pod per tensor; pods always sync the *same* parameter
    pytree, so every leaf is a ready-made bucket of P same-shape problems.
    ``jax.vmap`` over ``compress_delta`` keeps per-member results
    bit-identical to the serial path.  Returns (batched StaticTT with
    leading pod axis on every leaf, residuals (P, *shape)).
    """
    return jax.vmap(functools.partial(compress_delta, cfg=cfg))(deltas)


def pod_sync_tt(
    delta: jax.Array,
    cfg: CommCompressionConfig,
    axis_name: str = "pod",
) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map/pmap over ``axis_name``: TT-compress the local delta,
    all-gather the (small) cores across pods, reconstruct+average.

    Returns (averaged_delta, residual).
    """
    tt, resid = compress_delta(delta, cfg)
    gathered: List[jax.Array] = [
        jax.lax.all_gather(c, axis_name=axis_name) for c in tt.cores
    ]  # each: (n_pods, r, n, r')
    n_pods = jax.lax.psum(1, axis_name=axis_name)

    def rec_one(p):
        cores = [g[p] for g in gathered]
        acc = cores[0]
        for g in cores[1:]:
            r = g.shape[0]
            acc = acc.reshape(-1, r) @ g.reshape(r, -1)
        return acc.reshape(delta.shape)

    # newer jax: mark the accumulator axis-varying explicitly (no-op on old)
    init = compat.pvary(jnp.zeros(delta.shape, jnp.float32), (axis_name,))
    total = jax.lax.fori_loop(0, n_pods, lambda p, s: s + rec_one(p), init)
    avg = (total / n_pods).astype(delta.dtype)
    return avg, resid


def pod_sync_dense(delta: jax.Array, axis_name: str = "pod") -> jax.Array:
    """The uncompressed baseline: plain pmean over the pod axis."""
    return jax.lax.pmean(delta, axis_name=axis_name)


def fedttd_roundtrip(
    deltas: List[jax.Array],
    cfg: CommCompressionConfig,
    plan: str = "batched",
) -> Tuple[jax.Array, List[jax.Array], float]:
    """Single-process simulator of one cross-pod sync round (for tests).

    deltas: one tensor per pod.  Returns (average, residuals, payload_ratio)
    where payload_ratio = compressed_bytes / raw_bytes of the exchange.

    plan="batched" compresses all pods' deltas in one vmapped launch (the
    default); plan="serial" is the original per-pod loop, kept as the
    equivalence oracle — both produce identical numerics.
    """
    n_pods = len(deltas)
    if plan == "batched":
        batched, resid_stack = compress_delta_batched(
            jnp.stack(deltas), cfg
        )
        tts = [_tt.static_tt_member(batched, p) for p in range(n_pods)]
        resids = [resid_stack[p] for p in range(n_pods)]
    elif plan == "serial":
        tts, resids = [], []
        for d in deltas:
            tt, r = compress_delta(d, cfg)
            tts.append(tt)
            resids.append(r)
    else:
        raise ValueError(f"unknown plan: {plan!r}")
    avg = sum(
        _tt.static_tt_reconstruct(t).reshape(deltas[0].shape) for t in tts
    ) / n_pods
    raw = int(np.prod(deltas[0].shape)) * n_pods
    comp = sum(
        int(np.prod(c.shape)) for t in tts for c in t.cores
    )
    return avg.astype(deltas[0].dtype), resids, comp / raw
