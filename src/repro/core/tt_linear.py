"""TTLinear — apply a dense layer straight from its TT cores.

The paper's Fig. 1 receiving node reconstructs TT-shipped weights (eq.
(1)/(2) chained contractions) and then serves.  But those contractions ARE a
factored matmul: instead of materializing W = G_1 ×₁ … ×₁ G_N once
(O(∏ n_k) bytes resident for the model's lifetime), the forward pass can
contract the activation through the cores per token — the TT-layer
formulation of Novikov et al. (surveyed in Liu & Parhi, arXiv 2304.13539)
and the storage/bandwidth-bound serving mode of the TT-LLM accelerator work
(arXiv 2501.19135).  On memory-bound decode, weight bytes *are* the decode
latency, so shipping cores instead of dense weights is both the memory and
the speed win.

Representation
--------------
A ``TTLinear`` wraps one (optionally layer-stacked) weight:

  * ``lead``  — ``(L, r_s)`` per-layer boundary vectors: the layer-stack
                modes of the joint TT contracted at every concrete layer
                index (host-side, at conversion).  ``None`` for unstacked
                weights.  Inside a ``lax.scan`` over layers, selecting
                ``lead[l]`` is a tiny gather — the *shared* in/out cores
                stay closure constants, so HLO size remains depth-
                independent and cores are never duplicated per layer.
  * ``cores`` — the remaining input/output cores, shared by every layer.
  * ``split`` — how many of ``cores`` are input cores (contracted against
                the activation); the rest expand the output modes.
  * ``experts`` — MoE expert banks keep one extra lead mode: the stacked
                lead table is ``(L, E, r_s)`` and ``select_layer`` yields
                ``(E, r_s)`` — a per-expert family of chains over the SAME
                shared cores, applied by ``tt_apply_experts`` through the
                expert-batched kernel path (``tt_contract_batched``).

``tt_apply`` runs the lead-absorbed chain through the fused Pallas kernels
(``kernels/tt_contract``), falling back to the einsum chain for deep TTs.
Because the contraction order matches ``tt_reconstruct`` exactly, TT-native
logits equal reconstruct-then-serve logits to numerical precision — well
inside the compression ε bound.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tt as _tt


@dataclass
class TTLinear:
    lead: Optional[jax.Array]        # (L[, E], r_s) stacked | ([E,] r_s) | None
    cores: List[jax.Array]           # [g (r,n,s), ...]; cores[0] r == r_s
    split: int                       # number of input cores
    in_shape: Tuple[int, ...]        # dense-weight input dims, e.g. (D,)
    out_shape: Tuple[int, ...]       # dense-weight output dims, e.g. (H, K)
    dtype: Any = jnp.bfloat16        # activation dtype of the dense original
    experts: Optional[int] = None    # expert-bank size E (extra lead mode
                                     # kept as a batch axis at apply time)

    @property
    def stacked(self) -> bool:
        """True while the per-layer lead table still carries its L axis."""
        if self.lead is None:
            return False
        return self.lead.ndim == (3 if self.experts else 2)

    @property
    def num_layers(self) -> Optional[int]:
        if self.stacked:
            return int(self.lead.shape[0])
        return None

    @property
    def payload_params(self) -> int:
        n = sum(int(np.prod(c.shape)) for c in self.cores)
        if self.lead is not None:
            n += int(np.prod(self.lead.shape))
        return n


def _ttl_flatten(t: TTLinear):
    return (
        (t.lead, t.cores),
        (t.split, t.in_shape, t.out_shape, jnp.dtype(t.dtype).name,
         t.experts),
    )


def _ttl_unflatten(aux, kids):
    split, in_shape, out_shape, dtype, experts = aux
    return TTLinear(
        lead=kids[0], cores=kids[1], split=split,
        in_shape=in_shape, out_shape=out_shape, dtype=jnp.dtype(dtype),
        experts=experts,
    )


jax.tree_util.register_pytree_node(TTLinear, _ttl_flatten, _ttl_unflatten)


def is_tt_linear(x) -> bool:
    return isinstance(x, TTLinear)


def select_layer(t: TTLinear, idx) -> TTLinear:
    """Layer ``idx``'s view of a stacked TTLinear: gather its lead vector
    (``idx`` may be traced — this is what runs inside the layer scan);
    cores are shared and pass through untouched.

    Out-of-range ``idx`` is pinned to CLAMP (``mode="clip"``): a traced
    index beyond the stack returns the last layer's lead instead of jnp's
    default fill-with-NaN — deterministic, and identical between traced and
    concrete indices."""
    if not t.stacked:
        return t
    return TTLinear(
        lead=jnp.take(t.lead, idx, axis=0, mode="clip"), cores=t.cores,
        split=t.split, in_shape=t.in_shape, out_shape=t.out_shape,
        dtype=t.dtype, experts=t.experts,
    )


def tt_apply(x: jax.Array, t: TTLinear) -> jax.Array:
    """y = x · W from cores alone; x (..., *in_shape) → (..., *out_shape)."""
    assert not t.experts, "expert-bank TTLinear: use tt_apply_experts"
    assert t.lead is None or t.lead.ndim == 1, (
        "stacked TTLinear: select_layer() before apply"
    )
    nin = len(t.in_shape)
    assert x.shape[x.ndim - nin:] == tuple(t.in_shape), (x.shape, t.in_shape)
    batch = x.shape[: x.ndim - nin]
    x2 = x.reshape(int(np.prod(batch or (1,))), -1)

    g0 = t.cores[0]                                   # (r_s, n_1, r_1)
    if t.lead is not None:
        g0 = jnp.einsum(
            "r,rns->ns", t.lead.astype(jnp.float32), g0.astype(jnp.float32)
        )
    else:
        assert g0.shape[0] == 1, g0.shape
        g0 = g0[0]
    chain = [g0] + list(t.cores[1:])

    from repro.kernels.tt_contract.ops import tt_contract  # lazy: no cycle
    y2 = tt_contract(x2, chain, split=t.split)
    return y2.reshape(*batch, *t.out_shape).astype(x.dtype)


def tt_apply_experts(x: jax.Array, t: TTLinear) -> jax.Array:
    """Expert-banked apply: y[e] = x[e] · W[e] straight from cores.

    x (E, C, *in_shape) → (E, C, *out_shape).  Every expert shares the same
    in/out cores; only the tiny (E, r_s) lead table distinguishes them, so
    the whole bank contracts as ONE batched chain (``tt_contract_batched``)
    — the dense (E, N_in, N_out) bank is never materialized."""
    assert t.experts, "plain TTLinear: use tt_apply"
    assert t.lead is not None and t.lead.ndim == 2, (
        "stacked expert TTLinear: select_layer() before apply"
    )
    e = int(t.lead.shape[0])
    assert x.shape[0] == e, (x.shape, e)
    nin = len(t.in_shape)
    assert x.shape[x.ndim - nin:] == tuple(t.in_shape), (x.shape, t.in_shape)
    batch = x.shape[1: x.ndim - nin]
    x3 = x.reshape(e, int(np.prod(batch or (1,))), -1)

    # per-expert lead-absorbed first core: (E, r_s)·(r_s, n_1, r_1)
    g0e = jnp.einsum(
        "er,rns->ens", t.lead.astype(jnp.float32),
        t.cores[0].astype(jnp.float32),
    )
    from repro.kernels.tt_contract.ops import tt_contract_batched
    y3 = tt_contract_batched(x3, g0e, list(t.cores[1:]), split=t.split)
    return y3.reshape(e, *batch, *t.out_shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Conversion: TTCompressor payload (whole stacked tensor) → TTLinear
# ---------------------------------------------------------------------------

def _group_dims(tt_dims: Sequence[int], orig_shape: Sequence[int]):
    """Partition the tensorized dims into per-original-axis groups (greedy
    prefix products).  Returns group sizes or None when the dims are not a
    per-axis concatenation (e.g. padded bucket members)."""
    groups, i = [], 0
    for n in orig_shape:
        prod, start = 1, i
        while prod < n and i < len(tt_dims):
            prod *= tt_dims[i]
            i += 1
        if prod != n:
            return None
        groups.append(i - start)
    return groups if i == len(tt_dims) else None


def tt_linear_from_tt(
    tt: _tt.TTTensor,
    orig_shape: Sequence[int],
    stack: int,
    in_ndim: int,
    dtype=jnp.bfloat16,
    core_dtype=jnp.float32,
    experts: int = 0,
) -> Optional[TTLinear]:
    """Build a TTLinear from a whole-tensor TT of a (stacked) dense weight.

    orig_shape = (*stack_dims, *in_dims, *out_dims); ``stack`` leading axes
    are layer-stack modes (0 for unstacked), the next ``in_ndim`` axes are
    the matmul input.  The stack modes are contracted at every concrete
    layer index on the host, yielding the ``(L, r_s)`` lead table; in/out
    cores are shared across layers.  Returns None when the TT's dims don't
    map cleanly onto the axes (padded members) — caller falls back to
    reconstruction.

    experts: how many TRAILING stack axes form an expert bank (MoE weights
    (L, E, D, F) use stack=2, experts=1).  Their modes stay a batch axis of
    the lead table — (L, E, r_s) — instead of being scanned over, so one
    layer's whole bank applies as a single batched chain.

    core_dtype: storage dtype of the resident cores.  The contraction
    upcasts to f32 regardless; bf16 storage rounds the cores exactly like
    reconstruct-then-serve rounds the dense matrix, at half the bytes.
    """
    assert 0 <= experts <= stack
    groups = _group_dims(tt.shape, orig_shape)
    if groups is None:
        return None
    ns = sum(groups[:stack])                          # cores in the stack part
    split = sum(groups[stack: stack + in_ndim])
    if split < 1 or len(tt.cores) - ns - split < 1:
        return None                  # need ≥1 input core and ≥1 output core
    if experts and ns == 0:
        return None                  # expert bank needs its stack modes

    lead = None
    n_experts = None
    cores = [jnp.asarray(c, jnp.float32) for c in tt.cores]
    if ns > 0:
        # prefix-reconstruct the stack modes: (1,n_1,r_1) ×₁ … → (L, r_s)
        acc = cores[0].reshape(-1, cores[0].shape[2])  # (n_1, r_1)
        for k in range(1, ns):
            r, n, s = cores[k].shape
            acc = (acc @ cores[k].reshape(r, n * s)).reshape(-1, s)
        lead = acc                                    # (L[·E], r_s)
        if experts:
            n_experts = int(np.prod(orig_shape[stack - experts: stack]))
            lead = lead.reshape(-1, n_experts, lead.shape[-1])  # (L, E, r_s)
        cores = cores[ns:]
    cd = jnp.dtype(core_dtype)
    return TTLinear(
        lead=None if lead is None else lead.astype(cd),
        cores=[c.astype(cd) for c in cores], split=split,
        in_shape=tuple(orig_shape[stack: stack + in_ndim]),
        out_shape=tuple(orig_shape[stack + in_ndim:]),
        dtype=dtype,
        experts=n_experts,
    )


def tt_param_bytes(tree) -> int:
    """Resident weight bytes of a params pytree: TT leaves count their
    cores+lead payload, dense leaves their full array.  Non-array leaves
    (Python step counters and other scalars riding in checkpoint trees)
    carry no resident weight bytes and are skipped."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_tt_linear):
        if is_tt_linear(leaf):
            total += sum(int(c.size) * c.dtype.itemsize for c in leaf.cores)
            if leaf.lead is not None:
                total += int(leaf.lead.size) * leaf.lead.dtype.itemsize
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * leaf.dtype.itemsize
    return total


def spectral_decay_pytree(params, alpha: float = 1.0, min_size: int = 8192):
    """Impose a power-law singular spectrum (σ_i ∝ i^-α) on every big ≥2-D
    leaf.  Random init has a flat spectrum — incompressible by design, and
    the TT policy correctly refuses it; trained nets decay.  Demo/benchmark
    helper for exercising the TT serving path on synthetic weights."""
    def one(p):
        if p.ndim < 2 or p.size < min_size:
            return p
        mat = np.asarray(jax.device_get(p), np.float32).reshape(-1, p.shape[-1])
        u, s, vt = np.linalg.svd(mat, full_matrices=False)
        target = s[0] * (np.arange(1, s.size + 1.0) ** -alpha)
        out = (u * target) @ vt
        return jnp.asarray(out.reshape(p.shape), p.dtype)

    return jax.tree.map(one, params)
