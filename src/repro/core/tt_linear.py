"""TTLinear — apply a dense layer straight from its TT cores.

The paper's Fig. 1 receiving node reconstructs TT-shipped weights (eq.
(1)/(2) chained contractions) and then serves.  But those contractions ARE a
factored matmul: instead of materializing W = G_1 ×₁ … ×₁ G_N once
(O(∏ n_k) bytes resident for the model's lifetime), the forward pass can
contract the activation through the cores per token — the TT-layer
formulation of Novikov et al. (surveyed in Liu & Parhi, arXiv 2304.13539)
and the storage/bandwidth-bound serving mode of the TT-LLM accelerator work
(arXiv 2501.19135).  On memory-bound decode, weight bytes *are* the decode
latency, so shipping cores instead of dense weights is both the memory and
the speed win.

Quantized storage (int8, int4-ready)
------------------------------------
Decode is memory-bound, so the cores' *storage* bytes are the decode
latency.  ``quantize_tt`` rounds every core to a symmetric integer grid —
one scale per core (per-core absmax calibration), one scale per lead ROW
(per layer, and per (layer, expert) for expert banks) — and the fused
kernels dequantize *inside* the contraction: HBM streams int8, the MXU
computes f32, and the scale multiply folds into the per-tile epilogue
(``kernels/tt_contract`` q-variants).  The wide form of a stored core never
exists outside a VMEM tile; the only wide intermediate is the per-layer
lead-absorbed first core, which is transient activation-sized traffic (and
``r_s``× smaller than the core it absorbs).  With round-to-nearest the
absolute error per element is at most ``scale/2 = absmax/(2·qmax)`` —
``≲ 0.2%`` of the core's dynamic range for int8 — which is an order of
magnitude inside the TT truncation ε the payload already carries.

Representation
--------------
A ``TTLinear`` wraps one (optionally layer-stacked) weight:

  * ``lead``  — ``(L, r_s)`` per-layer boundary vectors: the layer-stack
                modes of the joint TT contracted at every concrete layer
                index (host-side, at conversion).  ``None`` for unstacked
                weights.  Inside a ``lax.scan`` over layers, selecting
                ``lead[l]`` is a tiny gather — the *shared* in/out cores
                stay closure constants, so HLO size remains depth-
                independent and cores are never duplicated per layer.
  * ``cores`` — the remaining input/output cores, shared by every layer.
  * ``split`` — how many of ``cores`` are input cores (contracted against
                the activation); the rest expand the output modes.
  * ``experts`` — MoE expert banks keep one extra lead mode: the stacked
                lead table is ``(L, E, r_s)`` and ``select_layer`` yields
                ``(E, r_s)`` — a per-expert family of chains over the SAME
                shared cores, applied by ``tt_apply_experts`` through the
                expert-batched kernel path (``tt_contract_batched``).

``tt_apply`` runs the lead-absorbed chain through the fused Pallas kernels
(``kernels/tt_contract``), falling back to the einsum chain for deep TTs.
Because the contraction order matches ``tt_reconstruct`` exactly, TT-native
logits equal reconstruct-then-serve logits to numerical precision — well
inside the compression ε bound.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tt as _tt


@dataclass
class TTLinear:
    lead: Optional[jax.Array]        # (L[, E], r_s) stacked | ([E,] r_s) | None
    cores: List[jax.Array]           # [g (r,n,s), ...]; cores[0] r == r_s
    split: int                       # number of input cores
    in_shape: Tuple[int, ...]        # dense-weight input dims, e.g. (D,)
    out_shape: Tuple[int, ...]       # dense-weight output dims, e.g. (H, K)
    dtype: Any = jnp.bfloat16        # activation dtype of the dense original
    experts: Optional[int] = None    # expert-bank size E (extra lead mode
                                     # kept as a batch axis at apply time)
    scales: Optional[List[jax.Array]] = None   # per-core () f32 dequant
                                     # scales (None = wide storage)
    lead_scale: Optional[jax.Array] = None     # per-lead-row f32 scales:
                                     # (L,) stacked / (L, E) experts / ()

    @property
    def quantized(self) -> bool:
        """True when the cores are stored on an integer grid."""
        return self.scales is not None

    @property
    def stacked(self) -> bool:
        """True while the per-layer lead table still carries its L axis."""
        if self.lead is None:
            return False
        return self.lead.ndim == (3 if self.experts else 2)

    @property
    def num_layers(self) -> Optional[int]:
        if self.stacked:
            return int(self.lead.shape[0])
        return None

    @property
    def payload_params(self) -> int:
        n = sum(int(np.prod(c.shape)) for c in self.cores)
        if self.lead is not None:
            n += int(np.prod(self.lead.shape))
        return n


def _ttl_flatten(t: TTLinear):
    return (
        (t.lead, t.cores, t.scales, t.lead_scale),
        (t.split, t.in_shape, t.out_shape, jnp.dtype(t.dtype).name,
         t.experts),
    )


def _ttl_unflatten(aux, kids):
    split, in_shape, out_shape, dtype, experts = aux
    return TTLinear(
        lead=kids[0], cores=kids[1], split=split,
        in_shape=in_shape, out_shape=out_shape, dtype=jnp.dtype(dtype),
        experts=experts, scales=kids[2], lead_scale=kids[3],
    )


jax.tree_util.register_pytree_node(TTLinear, _ttl_flatten, _ttl_unflatten)


def is_tt_linear(x) -> bool:
    return isinstance(x, TTLinear)


def select_layer(t: TTLinear, idx) -> TTLinear:
    """Layer ``idx``'s view of a stacked TTLinear: gather its lead vector
    (``idx`` may be traced — this is what runs inside the layer scan);
    cores are shared and pass through untouched.

    Out-of-range ``idx`` is pinned to CLAMP (``mode="clip"``): a traced
    index beyond the stack returns the last layer's lead instead of jnp's
    default fill-with-NaN — deterministic, and identical between traced and
    concrete indices."""
    if not t.stacked:
        return t
    return TTLinear(
        lead=jnp.take(t.lead, idx, axis=0, mode="clip"), cores=t.cores,
        split=t.split, in_shape=t.in_shape, out_shape=t.out_shape,
        dtype=t.dtype, experts=t.experts, scales=t.scales,
        lead_scale=(None if t.lead_scale is None
                    else jnp.take(t.lead_scale, idx, axis=0, mode="clip")),
    )


# ---------------------------------------------------------------------------
# Quantization: symmetric integer cores, per-core / per-lead-row scales
# ---------------------------------------------------------------------------

# storage formats the serving stack accepts; int4 rides the same machinery
# (qmax from jnp.iinfo) once a packed container lands in the checkpoint path
QUANT_DTYPES = {"int8": jnp.int8}


def quant_dtype(name: str):
    """Resolve a ``--weights tt-<name>`` / ``quant=<name>`` storage format."""
    try:
        return QUANT_DTYPES[name]
    except KeyError:
        raise ValueError(
            f"unknown quantized core format {name!r} "
            f"(supported: {sorted(QUANT_DTYPES)})"
        ) from None


def _calib_amax(a: jax.Array, calib: str, axis=None) -> jax.Array:
    """Calibration point of |a|: ``absmax`` (default) or ``pXX[.X]`` — the
    XX-th percentile of |a|, clipping the tail outliers into saturation in
    exchange for a finer grid on the bulk."""
    mag = jnp.abs(a.astype(jnp.float32))
    if calib == "absmax":
        return mag.max(axis=axis)
    if calib.startswith("p"):
        try:
            pct = float(calib[1:])
        except ValueError:
            pct = -1.0
        if 0.0 < pct <= 100.0:
            return jnp.percentile(mag, pct, axis=axis)
    raise ValueError(
        f"quant calibration must be 'absmax' or 'pXX' (percentile of |w|, "
        f"0 < XX <= 100), got {calib!r}"
    )


def quantize_array(a: jax.Array, dtype=jnp.int8, calib: str = "absmax",
                   axis=None) -> Tuple[jax.Array, jax.Array]:
    """(values, scale) of a symmetric integer quantization of ``a``.

    scale = amax/qmax per reduction group (whole array when ``axis`` is
    None, else per row over ``axis``); values = clip(round(a/scale)).
    All-zero groups pin scale to 1 so the round-trip stays exact.  With
    absmax calibration the max-|a| element lands exactly on ±qmax, so
    dequantize→requantize is idempotent (bit-identical values and scales) —
    the property the int8 checkpoint round-trip leans on."""
    qmax = jnp.iinfo(dtype).max
    amax = _calib_amax(a, calib, axis=axis)
    scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    s = scale if axis is None else jnp.expand_dims(scale, axis)
    q = jnp.clip(jnp.round(a.astype(jnp.float32) / s), -qmax, qmax)
    return q.astype(dtype), scale


def dequantize_array(q: jax.Array, scale: jax.Array, axis=None) -> jax.Array:
    """Inverse of ``quantize_array`` (f32 values; exact for the grid)."""
    s = scale if axis is None else jnp.expand_dims(scale, axis)
    return q.astype(jnp.float32) * s


def quantize_tt(t: TTLinear, dtype=jnp.int8,
                calib: str = "absmax") -> TTLinear:
    """Quantize a TTLinear's resident payload to symmetric integers.

    Each core gets ONE scale (per-core absmax — cores are the shared
    compressed payload, already balanced by the TT-SVD's norm split); the
    lead table gets one scale PER ROW over its rank axis (per layer, and
    per (layer, expert) for expert banks) because row magnitudes vary with
    layer depth.  Max absolute error per element is scale/2 =
    amax/(2·qmax): <= amax/254 for int8.  Apply-time dequantization stays
    inside the fused kernels (``tt_apply`` hands int8 cores + scales down
    to ``kernels/tt_contract``)."""
    assert not t.quantized, "TTLinear is already quantized"
    cores, scales = [], []
    for g in t.cores:
        q, s = quantize_array(g, dtype=dtype, calib=calib)
        cores.append(q)
        scales.append(s)
    lead, lead_scale = t.lead, None
    if lead is not None:
        lead, lead_scale = quantize_array(lead, dtype=dtype, calib=calib,
                                          axis=-1)
    return TTLinear(
        lead=lead, cores=cores, split=t.split, in_shape=t.in_shape,
        out_shape=t.out_shape, dtype=t.dtype, experts=t.experts,
        scales=scales, lead_scale=lead_scale,
    )


def dequantize_tt(t: TTLinear) -> TTLinear:
    """Back to wide (f32) storage — the parity oracle for the fused path."""
    assert t.quantized, "TTLinear is not quantized"
    cores = [dequantize_array(g, s) for g, s in zip(t.cores, t.scales)]
    lead = t.lead
    if lead is not None:
        lead = dequantize_array(lead, t.lead_scale, axis=-1)
    return TTLinear(
        lead=lead, cores=cores, split=t.split, in_shape=t.in_shape,
        out_shape=t.out_shape, dtype=t.dtype, experts=t.experts,
    )


def quantize_tt_tree(params, dtype=jnp.int8, calib: str = "absmax"):
    """Quantize every TTLinear leaf of a params pytree (raw leaves pass
    through untouched) — the one-call seam serve.py and the benchmarks use
    to turn a bf16-TT serving tree into the int8 one."""
    def one(leaf):
        if is_tt_linear(leaf) and not leaf.quantized:
            return quantize_tt(leaf, dtype=dtype, calib=calib)
        return leaf
    return jax.tree.map(one, params, is_leaf=is_tt_linear)


def tt_apply(x: jax.Array, t: TTLinear) -> jax.Array:
    """y = x · W from cores alone; x (..., *in_shape) → (..., *out_shape)."""
    assert not t.experts, "expert-bank TTLinear: use tt_apply_experts"
    assert t.lead is None or t.lead.ndim == 1, (
        "stacked TTLinear: select_layer() before apply"
    )
    nin = len(t.in_shape)
    assert x.shape[x.ndim - nin:] == tuple(t.in_shape), (x.shape, t.in_shape)
    batch = x.shape[: x.ndim - nin]
    x2 = x.reshape(int(np.prod(batch or (1,))), -1)

    g0 = t.cores[0]                                   # (r_s, n_1, r_1)
    lead = t.lead
    if lead is not None and t.quantized:
        # the lead row is tiny — dequantize it host-side; its scale and the
        # first core's scale fold into the (transient) absorbed core, so
        # the tail cores are the only wide-dequant work left for the kernel
        lead = dequantize_array(lead, t.lead_scale)
    if lead is not None:
        g0 = jnp.einsum(
            "r,rns->ns", lead.astype(jnp.float32), g0.astype(jnp.float32)
        )
    else:
        assert g0.shape[0] == 1, g0.shape
        g0 = g0[0].astype(jnp.float32)
    chain_scales = None
    if t.quantized:
        g0 = g0 * t.scales[0]
        chain_scales = [None] + list(t.scales[1:])    # tail stays int8
    chain = [g0] + list(t.cores[1:])

    from repro.kernels.tt_contract.ops import tt_contract  # lazy: no cycle
    y2 = tt_contract(x2, chain, split=t.split, scales=chain_scales)
    return y2.reshape(*batch, *t.out_shape).astype(x.dtype)


def tt_apply_experts(x: jax.Array, t: TTLinear) -> jax.Array:
    """Expert-banked apply: y[e] = x[e] · W[e] straight from cores.

    x (E, C, *in_shape) → (E, C, *out_shape).  Every expert shares the same
    in/out cores; only the tiny (E, r_s) lead table distinguishes them, so
    the whole bank contracts as ONE batched chain (``tt_contract_batched``)
    — the dense (E, N_in, N_out) bank is never materialized."""
    assert t.experts, "plain TTLinear: use tt_apply"
    assert t.lead is not None and t.lead.ndim == 2, (
        "stacked expert TTLinear: select_layer() before apply"
    )
    e = int(t.lead.shape[0])
    assert x.shape[0] == e, (x.shape, e)
    nin = len(t.in_shape)
    assert x.shape[x.ndim - nin:] == tuple(t.in_shape), (x.shape, t.in_shape)
    batch = x.shape[1: x.ndim - nin]
    x3 = x.reshape(e, int(np.prod(batch or (1,))), -1)

    # per-expert lead-absorbed first core: (E, r_s)·(r_s, n_1, r_1)
    lead = t.lead
    tail_scales = None
    if t.quantized:
        lead = dequantize_array(lead, t.lead_scale, axis=-1)  # (E, r_s)
        tail_scales = list(t.scales[1:])
    g0e = jnp.einsum(
        "er,rns->ens", lead.astype(jnp.float32),
        t.cores[0].astype(jnp.float32),
    )
    if t.quantized:
        g0e = g0e * t.scales[0]
    from repro.kernels.tt_contract.ops import tt_contract_batched
    y3 = tt_contract_batched(x3, g0e, list(t.cores[1:]), split=t.split,
                             scales=tail_scales)
    return y3.reshape(e, *batch, *t.out_shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Conversion: TTCompressor payload (whole stacked tensor) → TTLinear
# ---------------------------------------------------------------------------

def _group_dims(tt_dims: Sequence[int], orig_shape: Sequence[int]):
    """Partition the tensorized dims into per-original-axis groups (greedy
    prefix products).  Returns group sizes or None when the dims are not a
    per-axis concatenation (e.g. padded bucket members)."""
    groups, i = [], 0
    for n in orig_shape:
        prod, start = 1, i
        while prod < n and i < len(tt_dims):
            prod *= tt_dims[i]
            i += 1
        if prod != n:
            return None
        groups.append(i - start)
    return groups if i == len(tt_dims) else None


def tt_linear_from_tt(
    tt: _tt.TTTensor,
    orig_shape: Sequence[int],
    stack: int,
    in_ndim: int,
    dtype=jnp.bfloat16,
    core_dtype=jnp.float32,
    experts: int = 0,
) -> Optional[TTLinear]:
    """Build a TTLinear from a whole-tensor TT of a (stacked) dense weight.

    orig_shape = (*stack_dims, *in_dims, *out_dims); ``stack`` leading axes
    are layer-stack modes (0 for unstacked), the next ``in_ndim`` axes are
    the matmul input.  The stack modes are contracted at every concrete
    layer index on the host, yielding the ``(L, r_s)`` lead table; in/out
    cores are shared across layers.  Returns None when the TT's dims don't
    map cleanly onto the axes (padded members) — caller falls back to
    reconstruction.

    experts: how many TRAILING stack axes form an expert bank (MoE weights
    (L, E, D, F) use stack=2, experts=1).  Their modes stay a batch axis of
    the lead table — (L, E, r_s) — instead of being scanned over, so one
    layer's whole bank applies as a single batched chain.

    core_dtype: storage dtype of the resident cores.  The contraction
    upcasts to f32 regardless; bf16 storage rounds the cores exactly like
    reconstruct-then-serve rounds the dense matrix, at half the bytes.
    """
    assert 0 <= experts <= stack
    groups = _group_dims(tt.shape, orig_shape)
    if groups is None:
        return None
    ns = sum(groups[:stack])                          # cores in the stack part
    split = sum(groups[stack: stack + in_ndim])
    if split < 1 or len(tt.cores) - ns - split < 1:
        return None                  # need ≥1 input core and ≥1 output core
    if experts and ns == 0:
        return None                  # expert bank needs its stack modes

    lead = None
    n_experts = None
    cores = [jnp.asarray(c, jnp.float32) for c in tt.cores]
    if ns > 0:
        # prefix-reconstruct the stack modes: (1,n_1,r_1) ×₁ … → (L, r_s)
        acc = cores[0].reshape(-1, cores[0].shape[2])  # (n_1, r_1)
        for k in range(1, ns):
            r, n, s = cores[k].shape
            acc = (acc @ cores[k].reshape(r, n * s)).reshape(-1, s)
        lead = acc                                    # (L[·E], r_s)
        if experts:
            n_experts = int(np.prod(orig_shape[stack - experts: stack]))
            lead = lead.reshape(-1, n_experts, lead.shape[-1])  # (L, E, r_s)
        cores = cores[ns:]
    cd = jnp.dtype(core_dtype)
    return TTLinear(
        lead=None if lead is None else lead.astype(cd),
        cores=[c.astype(cd) for c in cores], split=split,
        in_shape=tuple(orig_shape[stack: stack + in_ndim]),
        out_shape=tuple(orig_shape[stack + in_ndim:]),
        dtype=dtype,
        experts=n_experts,
    )


def tt_param_bytes(tree) -> int:
    """Resident weight bytes of a params pytree: TT leaves count their
    FULL payload — cores, lead table, and (when quantized) every dequant
    scale array — dense leaves their full array.  The TT-leaf walk goes
    through ``jax.tree.leaves`` of the leaf itself, so a field added to the
    TTLinear pytree can never silently escape the accounting again (the
    quantization scales initially did).  Non-array leaves (Python step
    counters and other scalars riding in checkpoint trees) carry no
    resident weight bytes and are skipped."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_tt_linear):
        if is_tt_linear(leaf):
            for a in jax.tree.leaves(
                (leaf.lead, leaf.cores, leaf.scales, leaf.lead_scale)
            ):
                total += int(a.size) * a.dtype.itemsize
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * leaf.dtype.itemsize
    return total


def tt_leaf_bytes(tree) -> Tuple[int, int]:
    """(resident bytes of the TT-served leaves, dense bytes those leaves
    would occupy un-decomposed) — the byte pair the quantization roofline
    argument is about: what the ``tt_contract`` kernels actually stream
    vs the reconstruct-then-serve baseline.  Raw leaves (embeddings,
    norms) are identical between the serving modes and excluded from both
    sides."""
    tt_b, dense_b = 0, 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_tt_linear):
        if not is_tt_linear(leaf):
            continue
        for a in jax.tree.leaves(
            (leaf.lead, leaf.cores, leaf.scales, leaf.lead_scale)
        ):
            tt_b += int(a.size) * a.dtype.itemsize
        n = int(np.prod(leaf.in_shape)) * int(np.prod(leaf.out_shape))
        n *= (leaf.num_layers or 1) * (leaf.experts or 1)
        dense_b += n * jnp.dtype(leaf.dtype).itemsize
    return tt_b, dense_b


def spectral_decay_pytree(params, alpha: float = 1.0, min_size: int = 8192):
    """Impose a power-law singular spectrum (σ_i ∝ i^-α) on every big ≥2-D
    leaf.  Random init has a flat spectrum — incompressible by design, and
    the TT policy correctly refuses it; trained nets decay.  Demo/benchmark
    helper for exercising the TT serving path on synthetic weights."""
    def one(p):
        if p.ndim < 2 or p.size < min_size:
            return p
        mat = np.asarray(jax.device_get(p), np.float32).reshape(-1, p.shape[-1])
        u, s, vt = np.linalg.svd(mat, full_matrices=False)
        target = s[0] * (np.arange(1, s.size + 1.0) ** -alpha)
        out = (u * target) @ vt
        return jnp.asarray(out.reshape(p.shape), p.dtype)

    return jax.tree.map(one, params)
