"""Tensor-Train Decomposition (paper Algorithm 1) and TT reconstruction.

Two execution paths, one algorithm:

* ``ttd``        — the offline path: concrete shapes, truly dynamic δ-ranks
                   (NumPy orchestration around JAX SVDs).  This is what the
                   paper's processor runs end-to-end and what the Table-I /
                   Table-III benchmarks measure.
* ``ttd_static`` — the in-graph path: jittable, fixed max-rank cores with
                   zero-masked tails, usable inside a pjit'd train step for
                   TT-compressed cross-pod parameter sync
                   (``core/comm_compress.py``).

Plus ``tt_reconstruct`` (eq. (1)/(2): chained contractions, each one a
matrix multiplication + reshape — this is what the receiving node in Fig. 1
executes) and compression accounting helpers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import svd as _svd_fn
from repro.core import truncation as _trunc


@dataclass
class TTTensor:
    """A tensor in TT format: cores[k] has shape (r_{k-1}, n_k, r_k)."""

    cores: List[jax.Array]
    shape: Tuple[int, ...]           # original tensor shape (n_1..n_N)
    ranks: Tuple[int, ...]           # (r_0=1, r_1, ..., r_N=1) — live ranks
    eps: float = 0.0

    @property
    def num_params(self) -> int:
        return int(sum(int(np.prod(c.shape)) for c in self.cores))

    @property
    def live_params(self) -> int:
        """Parameter count at the live (δ-selected) ranks, even if cores are
        physically padded to max rank (static path)."""
        r = self.ranks
        return int(
            sum(r[k] * n * r[k + 1] for k, n in enumerate(self.shape))
        )

    @property
    def compression_ratio(self) -> float:
        return float(np.prod(self.shape)) / max(self.live_params, 1)


def _as_2d(x, rows):
    return x.reshape(rows, -1)


def ttd(
    w,
    eps: float = 0.05,
    dims: Optional[Sequence[int]] = None,
    svd_method: str = "two_phase",
    hbd_impl: str = "unblocked",
    max_rank: Optional[int] = None,
) -> TTTensor:
    """Paper Algorithm 1 — offline TT-SVD with dynamic δ-ranks.

    w: array-like; ``dims`` optionally re-tensorizes it (prod must match).
    eps: prescribed relative accuracy ε; guarantees
         ||W - W_R||_F <= ε ||W||_F  (Oseledets 2011, the bound the paper's
         δ = ε/√(d-1)·||W||_F per-step budget enforces).
    """
    w = np.asarray(jax.device_get(w), dtype=np.float32)
    if dims is not None:
        assert int(np.prod(dims)) == w.size, (dims, w.shape)
        w = w.reshape(tuple(dims))
    shape = w.shape
    d = w.ndim
    if d == 1:
        core = jnp.asarray(w[None, :, None])
        return TTTensor(cores=[core], shape=shape, ranks=(1, 1), eps=eps)

    frob = float(np.linalg.norm(w))
    delta = float(_trunc.delta_threshold(eps, d, frob))

    cores: List[jax.Array] = []
    ranks = [1]
    w_temp = w
    for k in range(d - 1):
        rows = ranks[-1] * shape[k]
        mat = _as_2d(w_temp, rows)                          # Reshape (line 7)
        res = _svd_fn(
            jnp.asarray(mat), method=svd_method, hbd_impl=hbd_impl
        )                                                   # SVD+Sorting (8-9)
        u = np.asarray(res.u)
        s = np.asarray(res.s)
        vt = np.asarray(res.vt)
        r = _trunc.truncation_rank(s, delta)                # δ-Trunc. (10)
        if max_rank is not None:
            r = min(r, max_rank)
        u, s, vt = u[:, :r], s[:r], vt[:r, :]
        w_temp = (s[:, None] * vt)                          # Σ_t V_t^T (11)
        cores.append(jnp.asarray(u.reshape(ranks[-1], shape[k], r)))
        ranks.append(r)
    cores.append(jnp.asarray(w_temp.reshape(ranks[-1], shape[-1], 1)))
    ranks.append(1)
    return TTTensor(cores=cores, shape=shape, ranks=tuple(ranks), eps=eps)


def tt_reconstruct(tt: TTTensor, dtype=None):
    """Eq. (1)/(2): W_R = G_1 ×₁ G_2 ×₁ … ×₁ G_N via matmul+reshape chain."""
    cores = tt.cores
    acc = cores[0]                                  # (1, n_1, r_1)
    for g in cores[1:]:
        r = g.shape[0]
        acc = _as_2d(acc, acc.size // r) @ _as_2d(g, r)     # contraction (2)
    out = acc.reshape(tt.shape)
    return out.astype(dtype) if dtype is not None else out


# ---------------------------------------------------------------------------
# In-graph (static-shape) TT-SVD
# ---------------------------------------------------------------------------

@dataclass
class StaticTT:
    """Jittable TT: stacked cores padded to max ranks, live ranks as array."""

    cores: List[jax.Array]            # cores[k]: (rmax_{k-1}, n_k, rmax_k)
    ranks: jax.Array                  # (N+1,) int32 live ranks (traced)
    shape: Tuple[int, ...]


def tt_max_ranks(shape: Sequence[int], max_rank: int) -> List[int]:
    """Theoretical TT max ranks min(prod-left, prod-right), clipped."""
    d = len(shape)
    out = [1]
    for k in range(1, d):
        left = int(np.prod(shape[:k]))
        right = int(np.prod(shape[k:]))
        out.append(min(left, right, max_rank))
    out.append(1)
    return out


@functools.partial(
    jax.jit, static_argnames=("eps", "max_rank", "svd_method", "hbd_impl")
)
def ttd_static(
    w: jax.Array,
    eps: float = 0.05,
    max_rank: int = 64,
    svd_method: str = "library",
    hbd_impl: str = "unblocked",
) -> StaticTT:
    """Algorithm 1 with static shapes: cores padded to max ranks, δ-rank
    tracked as a traced value and the tails zero-masked.

    The zero-masking makes the padded reconstruction *exactly equal* to the
    dynamic-rank reconstruction, while every shape stays compile-time
    constant — the property the in-graph comm-compression path relies on.
    """
    shape = w.shape
    d = w.ndim
    rmax = tt_max_ranks(shape, max_rank)
    frob = jnp.linalg.norm(w.astype(jnp.float32))
    delta = _trunc.delta_threshold(eps, d, frob)

    cores: List[jax.Array] = []
    ranks = [jnp.asarray(1, jnp.int32)]
    # w_temp lives padded: (rmax_k, prod(shape[k:]))
    w_temp = w.astype(jnp.float32).reshape(1, -1)
    for k in range(d - 1):
        rows = rmax[k] * shape[k]
        tail = int(np.prod(shape[k + 1:]))
        mat = w_temp.reshape(rows, tail)
        kdim = min(rows, tail)
        res = _svd_fn(mat, method=svd_method, hbd_impl=hbd_impl)
        u, s, vt, r = _trunc.truncate_masked(res.u, res.s, res.vt, delta)
        r = jnp.minimum(r, rmax[k + 1])
        keep = jnp.arange(kdim) < r
        u = u * keep[None, :].astype(u.dtype)
        s = s * keep.astype(s.dtype)
        vt = vt * keep[:, None].astype(vt.dtype)
        # pad/crop factor rank-dim to rmax[k+1]
        rk1 = rmax[k + 1]
        if kdim >= rk1:
            u, s, vt = u[:, :rk1], s[:rk1], vt[:rk1, :]
        else:
            u = jnp.pad(u, ((0, 0), (0, rk1 - kdim)))
            s = jnp.pad(s, (0, rk1 - kdim))
            vt = jnp.pad(vt, ((0, rk1 - kdim), (0, 0)))
        cores.append(u.reshape(rmax[k], shape[k], rk1))
        ranks.append(r)
        w_temp = s[:, None] * vt                       # (rmax_{k+1}, tail)
    cores.append(w_temp.reshape(rmax[d - 1], shape[d - 1], 1))
    ranks.append(jnp.asarray(1, jnp.int32))
    return StaticTT(cores=cores, ranks=jnp.stack(ranks), shape=shape)


@functools.partial(
    jax.jit, static_argnames=("eps", "max_rank", "svd_method", "hbd_impl")
)
def ttd_static_batched(
    w: jax.Array,
    eps: float = 0.05,
    max_rank: int = 64,
    svd_method: str = "library",
    hbd_impl: str = "unblocked",
) -> StaticTT:
    """Batched Algorithm 1: one launch decomposes a whole (B, n_1..n_N) stack.

    Every member runs the identical static-shape TT-SVD (``ttd_static``)
    under ``jax.vmap``, so the returned ``StaticTT`` carries batched leaves:
    cores[k] is (B, rmax_{k-1}, n_k, rmax_k) and ``ranks`` is (B, N+1).
    Per-member results are bit-identical to serial ``ttd_static`` calls —
    the equivalence the batched compression planner relies on.
    """
    fn = functools.partial(
        ttd_static, eps=eps, max_rank=max_rank,
        svd_method=svd_method, hbd_impl=hbd_impl,
    )
    return jax.vmap(fn)(w)


def static_tt_member(tt: StaticTT, i: int) -> StaticTT:
    """Member ``i`` of a batched StaticTT (host-side view)."""
    return StaticTT(
        cores=[c[i] for c in tt.cores], ranks=tt.ranks[i], shape=tt.shape
    )


def static_tt_crop(tt: StaticTT, eps: float = 0.0) -> TTTensor:
    """Crop an (unbatched) StaticTT's zero-masked rank padding away.

    The live-rank slices of the padded cores reconstruct exactly the padded
    product (the masked tails contribute nothing), so this converts the
    in-graph result into the compact host-side ``TTTensor`` the offline
    compressor trades in.
    """
    ranks = [int(r) for r in np.asarray(jax.device_get(tt.ranks))]
    cores = [
        jnp.asarray(np.asarray(jax.device_get(c))[: ranks[k], :, : ranks[k + 1]])
        for k, c in enumerate(tt.cores)
    ]
    return TTTensor(cores=cores, shape=tt.shape, ranks=tuple(ranks), eps=eps)


def static_tt_reconstruct(tt: StaticTT) -> jax.Array:
    acc = tt.cores[0]
    for g in tt.cores[1:]:
        r = g.shape[0]
        acc = acc.reshape(-1, r) @ g.reshape(r, -1)
    return acc.reshape(tt.shape)


jax.tree_util.register_pytree_node(
    StaticTT,
    lambda t: ((t.cores, t.ranks), t.shape),
    lambda shape, kids: StaticTT(cores=kids[0], ranks=kids[1], shape=shape),
)


# ---------------------------------------------------------------------------
# Tensorization helpers
# ---------------------------------------------------------------------------

def auto_factorize(n: int, max_factor: int = 64) -> List[int]:
    """Split n into balanced factors ≤ max_factor (for re-tensorizing
    matrices/vectors into TT-friendly shapes, TT-Rec-style)."""
    if n <= max_factor:
        return [n]
    best = None
    f = int(np.floor(np.sqrt(n)))
    for cand in range(f, 1, -1):
        if n % cand == 0:
            a, b = cand, n // cand
            left = auto_factorize(a, max_factor)
            right = auto_factorize(b, max_factor)
            best = left + right
            break
    if best is None:  # prime > max_factor: keep as-is
        return [n]
    return best


def tensorize_shape(shape: Sequence[int], max_factor: int = 64) -> List[int]:
    dims: List[int] = []
    for n in shape:
        dims.extend(auto_factorize(int(n), max_factor))
    return dims
