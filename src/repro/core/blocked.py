"""Blocked (WY) Householder bidiagonalization — the MXU-native variant.

This is the recorded *beyond-paper* optimization of phase 1.  The paper's
HBD-ACC applies each reflector to the full trailing matrix as two GEMVs
through a 16×16 GEMM array (the rank-1 update path).  On a TPU the MXU wants
128-aligned GEMMs with high arithmetic intensity, so we use the classical
LAPACK-style restructuring (same arithmetic, different schedule):

  * factor a *panel* of ``panel`` columns/rows with the unblocked
    paper algorithm, keeping the panel (and its Householder vectors) in fast
    memory — the direct analogue of TT-Edge's "Householder vectors stay in
    the SPM";
  * aggregate the panel's reflectors into compact WY form
    (H_1 ... H_b = I - V T V^T) and apply them to the trailing matrix as
    two large GEMMs — the analogue of "reuse the GEMM accelerator", scaled
    to MXU shapes.

For simplicity and robustness we implement the *one-sided* blocked scheme:
QR-by-blocks to upper-triangularize (R), then bidiagonalize the small R
with the unblocked paper kernel.  For tall matrices (M >> N) this is the
standard LAPACK dgesvd "QR-first" path and moves ~all FLOPs into GEMM form.
U_B/B/V_B^T satisfy exactly the same contract as
``hbd.householder_bidiagonalize``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import hbd as _hbd


def _house_vec(x: jax.Array, mask: jax.Array):
    """HOUSE with LAPACK normalization v[i0] = 1; returns (v, tau, beta_pivot).

    H = I - tau v v^T reproduces exactly the paper's reflector.
    """
    x = jnp.where(mask, x, 0.0)
    norm = jnp.linalg.norm(x)
    i0 = jnp.argmax(mask)
    x1 = x[i0]
    s = jnp.where(x1 >= 0, 1.0, -1.0).astype(x.dtype)
    pivot = -s * norm                       # value that lands on the diagonal
    v1 = x1 + s * norm
    safe = jnp.abs(v1) > 0
    v = jnp.where(mask, x / jnp.where(safe, v1, 1.0), 0.0)
    v = v.at[i0].set(jnp.where(safe, 1.0, 0.0))
    tau = jnp.where(safe, (s * v1) / jnp.where(norm == 0, 1.0, norm), 0.0)
    return v, tau, pivot


def panel_qr(a: jax.Array, col0: int, panel: int):
    """Factor columns [col0, col0+panel) of A by Householder QR (unblocked).

    Returns (a_updated, V (M,panel), taus (panel,)) where V holds the
    normalized Householder vectors.  ``col0`` must be a static int.
    """
    m, n = a.shape
    rows = jnp.arange(m)
    vs = jnp.zeros((m, panel), a.dtype)
    taus = jnp.zeros((panel,), a.dtype)

    def step(j, carry):
        a_, vs_, taus_ = carry
        c = col0 + j
        mask = rows >= c
        v, tau, pivot = _house_vec(a_[:, c], mask)
        # apply H = I - tau v v^T to the panel's remaining columns only;
        # the trailing matrix is updated once per panel in WY form.
        upto = col0 + panel
        colmask = (jnp.arange(n) >= c) & (jnp.arange(n) < upto)
        w = v @ jnp.where(colmask[None, :], a_, 0.0)         # GEMM #1
        a_ = a_ - tau * jnp.outer(v, jnp.where(colmask, w, 0.0))  # GEMM #2
        a_ = a_.at[c, c].set(pivot)  # wait-free: H zeroes below, pivot on diag
        a_ = a_.at[:, c].set(jnp.where(rows > c, v, a_[:, c]))
        vs_ = vs_.at[:, j].set(v)
        taus_ = taus_.at[j].set(tau)
        return a_, vs_, taus_

    a, vs, taus = jax.lax.fori_loop(0, panel, step, (a, vs, taus))
    return a, vs, taus


def build_t(vs: jax.Array, taus: jax.Array) -> jax.Array:
    """Compact-WY T factor: H_1...H_b = I - V T V^T (LARFT forward/columnwise)."""
    b = taus.shape[0]
    vtv = vs.T @ vs  # (b, b)

    def step(j, t):
        tj = taus[j]
        col = -tj * (t @ (vtv[:, j] * (jnp.arange(b) < j)))
        col = jnp.where(jnp.arange(b) == j, tj, col)
        col = jnp.where(jnp.arange(b) < j, col, jnp.where(jnp.arange(b) == j, tj, 0.0))
        return t.at[:, j].set(col)

    t0 = jnp.zeros((b, b), vs.dtype)
    return jax.lax.fori_loop(0, b, step, t0)


def apply_wy_left(a: jax.Array, vs: jax.Array, t: jax.Array) -> jax.Array:
    """A <- (I - V T V^T)^T A = A - V T^T (V^T A): two MXU GEMM pairs.

    This is the kernel realized in ``kernels/block_update``.
    """
    w = vs.T @ a              # (b, N)
    return a - vs @ (t.T @ w)  # (M, N)


@functools.partial(jax.jit, static_argnames=("panel",))
def blocked_qr(a: jax.Array, panel: int = 32):
    """Blocked Householder QR: A = Q R with Q = prod(I - tau v v^T).

    Returns (q (M,N) thin, r (N,N)).
    """
    m, n = a.shape
    if n % panel != 0:
        pad = panel - n % panel
        a = jnp.pad(a, ((0, 0), (0, pad)))
        q, r = blocked_qr(a, panel=panel)
        return q[:, :n], r[:n, :n]

    nblocks = n // panel
    all_vs = jnp.zeros((nblocks, m, panel), a.dtype)
    all_ts = jnp.zeros((nblocks, panel, panel), a.dtype)

    def block_step(k, carry):
        a_, vs_acc, ts_acc = carry
        # NOTE: col0 must be traced here; panel_qr handles traced col0 because
        # masks are built from arithmetic on it.
        a_, vs, taus = panel_qr(a_, k * panel, panel)
        t = build_t(vs, taus)
        # trailing update, confined to columns >= (k+1)*panel
        cols = jnp.arange(n) >= (k + 1) * panel
        trail = jnp.where(cols[None, :], a_, 0.0)
        trail = apply_wy_left(trail, vs, t)
        a_ = jnp.where(cols[None, :], trail, a_)
        return a_, vs_acc.at[k].set(vs), ts_acc.at[k].set(t)

    a, all_vs, all_ts = jax.lax.fori_loop(
        0, nblocks, block_step, (a, all_vs, all_ts)
    )
    r = jnp.triu(a[:n, :n])

    # form thin Q by applying the block reflectors to I (backward)
    q = jnp.eye(m, n, dtype=a.dtype)

    def q_step(i, q_):
        k = nblocks - 1 - i
        vs, t = all_vs[k], all_ts[k]
        # Q <- (I - V T V^T) Q
        w = vs.T @ q_
        return q_ - vs @ (t @ w)

    q = jax.lax.fori_loop(0, nblocks, q_step, q)
    return q, r


def blocked_bidiagonalize(a: jax.Array, panel: int = 32):
    """QR-first bidiagonalization: A = Q R;  R = U_r B V_B^T  (unblocked HBD
    on the small N×N R) ⇒ A = (Q U_r) B V_B^T.

    Same contract as ``hbd.householder_bidiagonalize`` (thin U_B: M×N).
    """
    m, n = a.shape
    q, r = blocked_qr(a, panel=panel)
    u_r, b, v_bt = _hbd.householder_bidiagonalize(r)
    return q @ u_r, b, v_bt


@functools.partial(jax.jit, static_argnames=("panel",))
def blocked_bidiagonalize_batched(a: jax.Array, panel: int = 32):
    """Batched WY/QR-first bidiagonalization of a (B, M, N) stack.

    One launch per bucket: the blocked panel/WY schedule vmaps unchanged, so
    member k equals ``blocked_bidiagonalize(a[k], panel)`` exactly.
    """
    if a.ndim != 3:
        raise ValueError(f"expected (B, M, N), got {a.shape}")
    return jax.vmap(
        functools.partial(blocked_bidiagonalize, panel=panel)
    )(a)
