"""δ-Truncation (paper Alg. 1 lines 27-31) and the TRUNCATION-module math.

Two faces of the same rule:

* ``truncation_rank``      — concrete (host/NumPy) path with a dynamic rank,
                             used by the offline compressor.
* ``truncation_rank_static`` / ``truncate_masked`` — jittable path: the rank
                             is computed in-graph but factor shapes stay at
                             r_max with the tail *zero-masked*.  This mirrors
                             the paper's hardware, which also allocates
                             worst-case SPM buffers and tracks the live rank
                             r_k in a register.

The paper's rule (1-indexed): keep k columns where
    k = min { i : ||Σ_s[i:rank]||_F < δ }
(i.e. the smallest leading block whose *inclusive* tail already fits under
δ; the discarded strict tail then satisfies ||·||_F < δ).  If no i
satisfies the bound, everything is kept.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def delta_threshold(eps: float, num_dims: int, frob_norm) -> jax.Array:
    """δ = ε/√(d-1) · ||W||_F  (Alg. 1 line 5)."""
    return eps / np.sqrt(max(num_dims - 1, 1)) * frob_norm


def tail_norms(s: jax.Array) -> jax.Array:
    """t[i] = ||s[i:]||_2 — the TRUNCATION module's reverse-Frobenius scan."""
    tail_sq = jnp.cumsum((s * s)[::-1])[::-1]
    return jnp.sqrt(tail_sq)


def truncation_rank(s: np.ndarray, delta: float) -> int:
    """Concrete-rank δ-truncation (paper semantics, 0-indexed result)."""
    s = np.asarray(s)
    t = np.sqrt(np.cumsum((s * s)[::-1])[::-1])
    hits = np.nonzero(t < delta)[0]
    if hits.size == 0:
        return int(s.shape[0])
    # paper keeps columns 1..k for the smallest 1-indexed i with tail < δ
    return max(int(hits[0]) + 1, 1) if hits[0] > 0 else 1


def truncation_rank_static(s: jax.Array, delta: jax.Array) -> jax.Array:
    """In-graph rank (same rule); returns a traced int32 scalar."""
    t = tail_norms(s)
    cond = t < delta
    any_hit = jnp.any(cond)
    first = jnp.argmax(cond)  # first True (cond is monotone non-decreasing)
    rank = jnp.where(any_hit, jnp.maximum(first + 1, 1), s.shape[0])
    # never exceed the number of singular values; rank 0 is not a TT rank
    return jnp.clip(rank, 1, s.shape[0]).astype(jnp.int32)


def truncate_masked(
    u: jax.Array, s: jax.Array, vt: jax.Array, delta: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Jittable δ-truncation with static shapes: tail columns/rows zeroed.

    Returns (U_t, Σ_t, V_t^T, rank) where the factors keep their full
    min(M,N) extent but entries beyond ``rank`` are exactly zero, so
    U_t diag(Σ_t) V_t^T equals the dynamically-truncated product.
    """
    rank = truncation_rank_static(s, delta)
    k = jnp.arange(s.shape[0])
    keep = k < rank
    return (
        u * keep[None, :].astype(u.dtype),
        s * keep.astype(s.dtype),
        vt * keep[:, None].astype(vt.dtype),
        rank,
    )
