"""Batched-compression planning: pytree walk → shape buckets → launch plan.

The serial ``TTCompressor`` loop pays one full dispatch+JIT round per
parameter; a ResNet-32 checkpoint has 31 conv tensors but only a handful of
distinct shapes.  The planner exploits that: it walks the parameter pytree,
applies the policy's raw/TT routing, and groups every TT-bound parameter
into a :class:`Bucket` keyed by its (padded) tensorized shape, so the
executor (``core/batch_exec.py``) can decompose each bucket with ONE batched
kernel launch instead of ``len(bucket)`` serial ones.

Planning is a pure function of the pytree's (paths, shapes, dtypes) and the
policy — two calls on the same inputs produce bitwise-identical plans
(asserted by ``CompressionPlan.fingerprint`` in tests and benchmarks).

Bucketing with padding
----------------------
Two parameters share a bucket when their tensorized dims are equal, OR when
the smaller one can be zero-padded up to the larger's dims at a bounded
element overhead (``pad_tolerance``).  Zero-padding is sound for the δ-rule:
padding leaves ‖W‖_F unchanged, so the padded decomposition satisfies
‖W_pad − R_pad‖_F ≤ ε‖W‖_F, and cropping the reconstruction back to the
original extent can only shrink the error.  Padded members therefore keep
the same ε guarantee as the serial path (property-tested).

Scheduling
----------
Each bucket also carries an execution mode: buckets whose *padded* unfolding
work would dwarf the serial dynamic-rank path (huge theoretical max ranks)
are routed back to the serial loop — the planner's cost model keeps the
batched path a strict win.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import tt as _tt


@dataclass(frozen=True)
class PlanEntry:
    """One parameter's routing decision."""

    name: str                        # flattened pytree path
    index: int                       # position in jax.tree.flatten order
    shape: Tuple[int, ...]           # original parameter shape
    dims: Tuple[int, ...]            # tensorized dims (pre-padding)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass(frozen=True)
class Bucket:
    """A group of same-(padded)-shape TT targets = one batched launch."""

    dims: Tuple[int, ...]            # target dims every member pads up to
    members: Tuple[PlanEntry, ...]   # sorted by name — deterministic order
    execution: str                   # "batched" | "serial" (scheduler call)

    @property
    def batch(self) -> int:
        return len(self.members)

    @property
    def padded_size(self) -> int:
        return int(np.prod(self.dims))


@dataclass(frozen=True)
class CompressionPlan:
    buckets: Tuple[Bucket, ...]
    raw: Tuple[PlanEntry, ...]       # passthrough (too small / unfactorable)
    num_leaves: int

    @property
    def tt_params(self) -> int:
        return sum(b.batch for b in self.buckets)

    @property
    def batched_launches(self) -> int:
        return sum(1 for b in self.buckets if b.execution == "batched")

    @property
    def fingerprint(self) -> str:
        """Stable content hash — equal iff the plans are bitwise-identical."""
        h = hashlib.sha256()
        for b in self.buckets:
            h.update(repr((b.dims, b.execution,
                           [(m.name, m.index, m.shape, m.dims)
                            for m in b.members])).encode())
        h.update(repr([(e.name, e.index, e.shape) for e in self.raw]).encode())
        return h.hexdigest()

    def describe(self) -> str:
        lines = [f"plan: {self.tt_params} TT params in {len(self.buckets)} "
                 f"buckets, {len(self.raw)} raw"]
        for b in self.buckets:
            pads = sum(1 for m in b.members if m.dims != b.dims)
            lines.append(
                f"  bucket dims={b.dims} batch={b.batch} "
                f"exec={b.execution}" + (f" (padded members: {pads})"
                                         if pads else "")
            )
        return "\n".join(lines)


def tensorize_dims(shape: Tuple[int, ...], policy) -> List[int]:
    """Policy dim selection, shared by the planner and the serial
    compressor loop (compression.py imports this — single source of truth,
    so the two paths can never route a shape differently)."""
    if len(shape) >= policy.min_dims:
        return list(shape)
    dims = _tt.tensorize_shape(shape, policy.max_factor)
    if len(dims) < policy.min_dims:
        dims = _tt.tensorize_shape(shape, max(8, policy.max_factor // 8))
    return dims


def padded_work_estimate(dims: Sequence[int], max_rank: Optional[int]) -> int:
    """Σ_k (rmax_{k-1}·n_k·tail_k) — elements touched by the padded sweep.

    The static batched path pads every unfolding to the theoretical max
    ranks; when those explode (deep tensorizations of huge matrices) the
    dynamic-rank serial path is asymptotically cheaper and the scheduler
    must fall back to it.
    """
    cap = max_rank if max_rank is not None else 1 << 30
    rmax = _tt.tt_max_ranks(dims, cap)
    total = 0
    for k in range(len(dims) - 1):
        rows = rmax[k] * dims[k]
        tail = int(np.prod(dims[k + 1:]))
        total += rows * tail
    return total


def _leaf_paths(params) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return ["/".join(str(k) for k in path) for path, _ in flat]


def build_plan(
    params,
    policy,
    pad_tolerance: float = 0.25,
    serial_cutoff_elems: int = 1 << 24,
) -> CompressionPlan:
    """Deterministic planning pass over a parameter pytree.

    pad_tolerance: a member may join a larger bucket if padding inflates its
      element count by at most this fraction (0 disables padding merges).
    serial_cutoff_elems: buckets whose per-member padded sweep would touch
      more elements than this are scheduled ``execution="serial"``.
    """
    leaves, _ = jax.tree.flatten(params)
    paths = _leaf_paths(params)

    raw: List[PlanEntry] = []
    tt_entries: List[PlanEntry] = []
    for idx, (name, leaf) in enumerate(zip(paths, leaves)):
        shape = tuple(int(d) for d in leaf.shape)
        entry_dims = tuple(tensorize_dims(shape, policy))
        entry = PlanEntry(name=name, index=idx, shape=shape, dims=entry_dims)
        size = entry.size
        if size < policy.min_size or min(shape or (1,)) == 0:
            raw.append(entry)
        elif len(entry_dims) < 2:
            raw.append(entry)
        else:
            tt_entries.append(entry)

    # ---- bucketing: group by ndim, greedily absorb pad-compatible dims ----
    by_ndim: Dict[int, Dict[Tuple[int, ...], List[PlanEntry]]] = {}
    for e in tt_entries:
        by_ndim.setdefault(len(e.dims), {}).setdefault(e.dims, []).append(e)

    buckets: List[Bucket] = []
    for ndim in sorted(by_ndim):
        groups = by_ndim[ndim]
        # largest target first; ties broken lexicographically — deterministic
        order = sorted(
            groups, key=lambda d: (int(np.prod(d)), d), reverse=True
        )
        absorbed: set = set()
        for target in order:
            if target in absorbed:
                continue
            members = list(groups[target])
            tsize = int(np.prod(target))
            for cand in order:
                if cand == target or cand in absorbed:
                    continue
                fits = all(c <= t for c, t in zip(cand, target))
                overhead = tsize / int(np.prod(cand)) - 1.0
                if fits and overhead <= pad_tolerance:
                    members.extend(groups[cand])
                    absorbed.add(cand)
            members.sort(key=lambda m: (m.name, m.index))
            work = padded_work_estimate(target, policy.max_rank)
            execution = "batched" if work <= serial_cutoff_elems else "serial"
            buckets.append(Bucket(
                dims=target, members=tuple(members), execution=execution,
            ))
            absorbed.add(target)

    # stable global order: by dims signature
    buckets.sort(key=lambda b: (len(b.dims), b.dims))
    raw.sort(key=lambda e: e.index)
    return CompressionPlan(
        buckets=tuple(buckets), raw=tuple(raw), num_leaves=len(leaves)
    )
