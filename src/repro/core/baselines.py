"""Baseline tensor decompositions the paper compares against (Table I).

* Tucker Decomposition — truncated HOSVD with the same ε-budget semantics.
* Tensor-Ring Decomposition (TRD) — TR-SVD (Zhao et al. 2016 style): like
  TT-SVD but the first unfolding splits rank across the two ring ends, and
  cores close a ring (r_N = r_0 > 1 allowed).

Both reuse the same two-phase SVD machinery, so Table-I/III benchmarks can
compare methods under an identical compute substrate — mirroring the paper's
simulation setup.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import svd as _svd_fn
from repro.core import truncation as _trunc


# ---------------------------------------------------------------------------
# Tucker (truncated HOSVD)
# ---------------------------------------------------------------------------

@dataclass
class TuckerTensor:
    core: jax.Array                  # (r_1, ..., r_N)
    factors: List[jax.Array]         # factors[k]: (n_k, r_k)
    shape: Tuple[int, ...]

    @property
    def num_params(self) -> int:
        return int(np.prod(self.core.shape)) + int(
            sum(int(np.prod(f.shape)) for f in self.factors)
        )

    @property
    def compression_ratio(self) -> float:
        return float(np.prod(self.shape)) / max(self.num_params, 1)


def _unfold(w: np.ndarray, mode: int) -> np.ndarray:
    return np.moveaxis(w, mode, 0).reshape(w.shape[mode], -1)


def tucker_hosvd(
    w, eps: float = 0.05, svd_method: str = "two_phase"
) -> TuckerTensor:
    """Truncated HOSVD with per-mode δ = ε/√d · ||W||_F budget."""
    w = np.asarray(jax.device_get(w), dtype=np.float32)
    shape = w.shape
    d = w.ndim
    frob = float(np.linalg.norm(w))
    delta = eps / np.sqrt(d) * frob

    factors: List[np.ndarray] = []
    for mode in range(d):
        mat = _unfold(w, mode)
        res = _svd_fn(jnp.asarray(mat), method=svd_method)
        s = np.asarray(res.s)
        r = _trunc.truncation_rank(s, delta)
        factors.append(np.asarray(res.u)[:, :r])

    core = w
    for mode, f in enumerate(factors):
        core = np.moveaxis(
            (f.T @ _unfold(core, mode)).reshape(
                f.shape[1], *[s for i, s in enumerate(core.shape) if i != mode]
            ),
            0,
            mode,
        )
    return TuckerTensor(
        core=jnp.asarray(core),
        factors=[jnp.asarray(f) for f in factors],
        shape=shape,
    )


def tucker_reconstruct(t: TuckerTensor) -> jax.Array:
    core = np.asarray(t.core)
    for mode, f in enumerate(t.factors):
        fm = np.asarray(f)
        core = np.moveaxis(
            (fm @ _unfold(core, mode)).reshape(
                fm.shape[0], *[s for i, s in enumerate(core.shape) if i != mode]
            ),
            0,
            mode,
        )
    return jnp.asarray(core.reshape(t.shape))


# ---------------------------------------------------------------------------
# Tensor-Ring (TR-SVD)
# ---------------------------------------------------------------------------

@dataclass
class TRTensor:
    cores: List[jax.Array]           # cores[k]: (r_k, n_k, r_{k+1}), ring
    shape: Tuple[int, ...]
    ranks: Tuple[int, ...]           # (r_0, r_1, ..., r_N = r_0)

    @property
    def num_params(self) -> int:
        return int(sum(int(np.prod(c.shape)) for c in self.cores))

    @property
    def compression_ratio(self) -> float:
        return float(np.prod(self.shape)) / max(self.num_params, 1)


def tr_svd(w, eps: float = 0.05, svd_method: str = "two_phase") -> TRTensor:
    """TR-SVD: first unfolding's rank is split across the ring closure."""
    w = np.asarray(jax.device_get(w), dtype=np.float32)
    shape = w.shape
    d = w.ndim
    frob = float(np.linalg.norm(w))
    delta = eps / np.sqrt(d) * frob

    # step 1: split r_1 into (r_0, r_1') via the first unfolding
    mat = w.reshape(shape[0], -1)
    res = _svd_fn(jnp.asarray(mat), method=svd_method)
    u, s, vt = np.asarray(res.u), np.asarray(res.s), np.asarray(res.vt)
    r1 = max(_trunc.truncation_rank(s, delta), 1)
    # balanced split r1 = r0 * r1p (choose r0 = floor(sqrt(r1)) divisorish)
    r0 = int(np.floor(np.sqrt(r1)))
    while r1 % r0 != 0:
        r0 -= 1
    r1p = r1 // r0
    u, s, vt = u[:, :r1], s[:r1], vt[:r1, :]
    # core 1: (r0, n1, r1p) — reshape U's rank axis into the ring split
    g1 = u.reshape(shape[0], r0, r1p).transpose(1, 0, 2)
    cores = [jnp.asarray(g1)]
    ranks = [r0, r1p]

    # remaining cores: TT-style sweep on (r1p, rest..., r0)
    w_temp = (s[:, None] * vt).reshape(r0, r1p, -1).transpose(1, 2, 0)
    w_temp = w_temp.reshape(r1p, *shape[1:], r0)
    cur = w_temp
    for k in range(1, d - 1):
        rows = ranks[-1] * shape[k]
        mat = cur.reshape(rows, -1)
        res = _svd_fn(jnp.asarray(mat), method=svd_method)
        u, s, vt = np.asarray(res.u), np.asarray(res.s), np.asarray(res.vt)
        r = max(_trunc.truncation_rank(s, delta), 1)
        u, s, vt = u[:, :r], s[:r], vt[:r, :]
        cores.append(jnp.asarray(u.reshape(ranks[-1], shape[k], r)))
        ranks.append(r)
        cur = s[:, None] * vt
    cores.append(jnp.asarray(cur.reshape(ranks[-1], shape[-1], r0)))
    ranks.append(r0)
    return TRTensor(cores=cores, shape=shape, ranks=tuple(ranks))


def tr_reconstruct(t: TRTensor) -> jax.Array:
    """Ring contraction: trace over the closing bond."""
    cores = [np.asarray(c) for c in t.cores]
    acc = cores[0]                               # (r0, n1, r1)
    for g in cores[1:]:
        r = g.shape[0]
        acc = acc.reshape(-1, r) @ g.reshape(r, -1)
        acc = acc.reshape(t.ranks[0], -1, g.shape[-1])
    # acc: (r0, prod(n), r0) — close the ring with a trace over the bond
    out = np.trace(acc.transpose(1, 0, 2), axis1=1, axis2=2)
    return jnp.asarray(out.reshape(t.shape))
