"""Bucket execution: one (mesh-sharded) batched TT-SVD launch per bucket.

Consumes the :class:`~repro.core.plan.CompressionPlan` produced by the
planning pass and runs each bucket through ``ttd_static_batched`` — the
vmapped static-shape Algorithm 1 whose per-member results are bit-identical
to serial ``ttd_static`` calls.  The padded cores come back to the host and
are cropped to their live δ-ranks, yielding the same compact ``TTTensor``
payloads the serial loop produces.

Scheduling
----------
* **Round-robin device sharding** — when a ``launch/mesh.py`` mesh is
  supplied, bucket members are assigned to devices round-robin over the
  ``data`` axis: member lists are chunked per device, each device's chunk is
  stacked contiguously, and the stacked batch axis is block-sharded with a
  ``NamedSharding`` — block-of-round-robin-chunks ≡ the round-robin
  assignment.  Results are gathered back to host for cropping.
* **Executable cache** — compiled bucket executables are cached by
  (batch, dims, ε, max-rank, svd method, hbd impl); recurring bucket shapes
  (the common case across checkpoints of the same model) pay JIT once.
* **Serial fallback** — buckets the planner marked ``execution="serial"``
  (padded-rank work estimate too high) run the classic per-param dynamic
  path unchanged.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tt as _tt
from repro.core.plan import Bucket, CompressionPlan

# Rank cap standing in for "uncapped" on the static path: tt_max_ranks takes
# the min with the theoretical ranks, so any large value means "exact".
_UNCAPPED = 1 << 30


@dataclass
class ExecStats:
    """Dispatch accounting for the batched vs serial execution paths."""

    bucket_launches: int = 0          # batched executables actually launched
    serial_params: int = 0            # params routed through the serial loop
    serial_dispatches: int = 0        # SVD dispatches those serial params cost
    batched_params: int = 0           # params decomposed inside bucket launches
    serial_equiv_dispatches: int = 0  # what the all-serial loop would have cost
    cache_hits: int = 0
    compiles: int = 0
    per_bucket: List[Dict] = field(default_factory=list)

    @property
    def total_dispatches(self) -> int:
        return self.bucket_launches + self.serial_dispatches

    @property
    def dispatch_reduction(self) -> float:
        return self.serial_equiv_dispatches / max(self.total_dispatches, 1)


def round_robin_chunks(n: int, ndev: int) -> List[List[int]]:
    """Member indices per device under round-robin assignment.

    Deterministic: member i goes to device ``i % ndev``.  Chunks are padded
    (with -1 sentinels) to equal length so the concatenated batch axis can
    be block-sharded — block-of-chunks realizes exactly this assignment.
    """
    ndev = max(1, ndev)
    chunks = [[i for i in range(n) if i % ndev == d] for d in range(ndev)]
    chunk_len = max((len(c) for c in chunks), default=0)
    for c in chunks:
        c.extend([-1] * (chunk_len - len(c)))
    return chunks


# module-level so repeated compressor instances share compiled executables
_EXEC_CACHE: Dict[Tuple, object] = {}


class BucketExecutor:
    """Runs a CompressionPlan's buckets; returns per-leaf TTTensors."""

    def __init__(self, mesh=None, data_axis: str = "data"):
        self.mesh = mesh
        self.data_axis = data_axis
        self.stats = ExecStats()

    # -- executable cache -------------------------------------------------
    def _compiled(self, stacked: jax.Array, policy):
        """AOT-compiled bucket executable, cached by (batch shape, policy).

        ``ttd_static_batched.lower(...).compile()`` bakes the static policy
        args and the input aval (including its sharding) into an XLA
        executable; recurring bucket shapes — the common case across
        checkpoints of the same model — skip lower+compile entirely on
        later launches.
        """
        statics = dict(
            eps=float(policy.eps),
            max_rank=(policy.max_rank if policy.max_rank is not None
                      else _UNCAPPED),
            svd_method=policy.svd_method,
            hbd_impl=policy.hbd_impl,
        )
        key = (
            stacked.shape, str(stacked.sharding), self._ndev(),
            tuple(sorted(statics.items())),
        )
        fn = _EXEC_CACHE.get(key)
        if fn is None:
            try:
                fn = _tt.ttd_static_batched.lower(
                    stacked, **statics
                ).compile()
            except Exception:      # AOT unavailable: fall back to lazy jit
                fn = functools.partial(_tt.ttd_static_batched, **statics)
            _EXEC_CACHE[key] = fn
            self.stats.compiles += 1
        else:
            self.stats.cache_hits += 1
        return fn

    # -- device placement -------------------------------------------------
    def _ndev(self) -> int:
        if self.mesh is None:
            return 1
        try:
            from repro.launch.mesh import data_axis_size
            return data_axis_size(self.mesh, self.data_axis)
        except Exception:
            return 1

    def _place(self, stacked: jax.Array) -> jax.Array:
        """Block-shard the batch axis over the data axis (no-op off-mesh)."""
        ndev = self._ndev()
        if ndev <= 1 or stacked.shape[0] % ndev != 0:
            return stacked
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(self.data_axis, *([None] * (stacked.ndim - 1)))
        return jax.device_put(stacked, NamedSharding(self.mesh, spec))

    # -- bucket execution --------------------------------------------------
    def run_bucket(
        self, bucket: Bucket, leaves: List, policy
    ) -> List[Tuple[int, _tt.TTTensor, Tuple[int, ...]]]:
        """Decompose one bucket; returns (leaf_index, tt, pre_pad_dims)."""
        d = len(bucket.dims)
        if bucket.execution == "serial" or d < 2:
            out = []
            for m in bucket.members:
                tt = _tt.ttd(
                    leaves[m.index], eps=policy.eps, dims=list(m.dims),
                    svd_method=policy.svd_method, hbd_impl=policy.hbd_impl,
                    max_rank=policy.max_rank,
                )
                out.append((m.index, tt, m.dims))
            self.stats.serial_params += len(bucket.members)
            self.stats.serial_dispatches += len(bucket.members) * max(d - 1, 1)
            return out

        # round-robin member→device chunks, zero-padding ragged tails
        chunks = round_robin_chunks(bucket.batch, self._ndev())
        order = [i for chunk in chunks for i in chunk]
        mats = []
        for i in order:
            if i < 0:
                mats.append(np.zeros(bucket.dims, np.float32))
                continue
            m = bucket.members[i]
            x = np.asarray(
                jax.device_get(leaves[m.index]), np.float32
            ).reshape(m.dims)
            if m.dims != bucket.dims:
                x = np.pad(x, [(0, t - c) for c, t in zip(m.dims, bucket.dims)])
            mats.append(x)
        stacked = self._place(jnp.asarray(np.stack(mats)))

        fn = self._compiled(stacked, policy)
        batched = fn(stacked)                       # ONE launch per bucket
        self.stats.bucket_launches += 1
        self.stats.batched_params += bucket.batch
        self.stats.per_bucket.append({
            "dims": bucket.dims, "batch": bucket.batch,
            "launch_batch": len(order), "devices": self._ndev(),
        })

        out = []
        for pos, i in enumerate(order):
            if i < 0:
                continue
            m = bucket.members[i]
            member = _tt.static_tt_member(batched, pos)
            tt = _tt.static_tt_crop(member, eps=policy.eps)
            out.append((m.index, tt, m.dims))
        return out

    def run(self, plan: CompressionPlan, leaves: List, policy):
        """Execute every bucket; returns {leaf_index: (tt, pre_pad_dims)}."""
        results: Dict[int, Tuple[_tt.TTTensor, Tuple[int, ...]]] = {}
        for bucket in plan.buckets:
            for idx, tt, pre_pad in self.run_bucket(bucket, leaves, policy):
                results[idx] = (tt, pre_pad)
            self.stats.serial_equiv_dispatches += (
                bucket.batch * max(len(bucket.dims) - 1, 1)
            )
        return results
