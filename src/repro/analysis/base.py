"""Rule registry, findings, and suppression markers for the invariant linter.

The analyzer has two layers (see ``docs/ANALYSIS.md``):

  * program lint (``programlint``) — traces registered hot entry points to
    jaxprs / lowered / compiled HLO and asserts dataflow contracts (dtype
    discipline, no host callbacks, donation honored, VMEM tile plans);
  * convention lint (``astlint``) — AST rules over ``src/`` enforcing the
    repo's dispatch and threading conventions.

Both layers report :class:`Finding`s against :class:`Rule`s registered
here.  Source-level rules honor a narrow escape hatch::

    y = jnp.einsum(...)  # lint: skip[AST001] depthwise conv, not a matmul

A marker suppresses the named rule(s) on its own line; a marker on a
comment-only line also covers the statement that starts on the next line.
Unknown rule IDs in markers are themselves findings (AST005) so stale
suppressions can't linger silently.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Set

_SKIP_RE = re.compile(r"#\s*lint:\s*skip\[([A-Za-z0-9_,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One checkable invariant: stable ID, layer, and the contract it guards."""

    rule_id: str
    layer: str                  # "program" | "ast"
    title: str
    invariant: str              # one-line statement of the guarded contract
    guarded_since: str          # PR that introduced the invariant


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: rule, location, and a human-actionable message."""

    rule_id: str
    path: str                   # repo-relative file, or "entry:<name>"
    line: int                   # 1-based; 0 for whole-entry findings
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule_id}: {self.message}"


_REGISTRY: Dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if rule.rule_id in _REGISTRY:
        raise ValueError(f"duplicate rule id {rule.rule_id}")
    _REGISTRY[rule.rule_id] = rule
    return rule


def all_rules() -> Dict[str, Rule]:
    # Import for registration side effects; deferred to dodge the cycle
    # (astlint/programlint import base for `register`).
    from repro.analysis import astlint, programlint  # noqa: F401
    return dict(_REGISTRY)


def skip_markers(source: str) -> Dict[int, Set[str]]:
    """Map 1-based line number -> rule IDs suppressed on that line.

    A marker on a comment-only line also covers the next line, so a long
    statement can carry its justification above rather than trailing.
    """
    skips: Dict[int, Set[str]] = {}
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _SKIP_RE.search(text)
        if not m:
            continue
        ids = {part.strip() for part in m.group(1).split(",") if part.strip()}
        skips.setdefault(lineno, set()).update(ids)
        if text.lstrip().startswith("#"):
            skips.setdefault(lineno + 1, set()).update(ids)
    return skips


def suppressed(skips: Dict[int, Set[str]], rule_id: str,
               lineno: int, end_lineno: int | None = None) -> bool:
    """True when any line of the node's span (or the line above it) names
    ``rule_id`` in a skip marker."""
    for ln in range(lineno - 1, (end_lineno or lineno) + 1):
        if rule_id in skips.get(ln, ()):
            return True
    return False


def iter_findings_sorted(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule_id))
