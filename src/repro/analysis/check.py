"""CLI for the invariant linter: ``python -m repro.analysis.check``.

Runs both layers (AST convention lint + traced program lint) and reports
findings.  Exit status: 0 when clean, 1 when findings (or analyzer errors)
exist and ``--strict`` is set.  The CI ``analysis`` lane runs
``--strict``; locally, ``--fast`` trims the program sweep to one arch plus
the TT/int8 and admission entries.

    python -m repro.analysis.check --strict            # the CI gate
    python -m repro.analysis.check --fast --layer ast  # quick local loop
    python -m repro.analysis.check --list-rules        # rule catalog
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

from repro.analysis import astlint, programlint
from repro.analysis.base import Finding, all_rules, iter_findings_sorted


def _list_rules() -> str:
    rules = all_rules()
    lines = []
    for rid in sorted(rules):
        r = rules[rid]
        lines.append(f"{rid}  [{r.layer}]  {r.title}")
        lines.append(f"        {r.invariant}")
        lines.append(f"        guarded since: {r.guarded_since}")
    return "\n".join(lines)


def run_checks(layer: str = "all", fast: bool = False,
               rules: Optional[Sequence[str]] = None,
               entries: Optional[Sequence[str]] = None,
               root: str = ".") -> List[Finding]:
    rule_set = set(rules) if rules else None
    findings: List[Finding] = []
    if layer in ("all", "ast"):
        ast_rules = ({r for r in rule_set if r.startswith("AST")}
                     if rule_set else None)
        if ast_rules or rule_set is None:
            findings.extend(astlint.run(root, rules=ast_rules))
    if layer in ("all", "program"):
        prg_rules = ({r for r in rule_set if not r.startswith("AST")}
                     if rule_set else None)
        if prg_rules or rule_set is None:
            findings.extend(programlint.run(fast=fast, rules=prg_rules,
                                            entries=entries))
    return iter_findings_sorted(findings)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="invariant linter: jaxpr/HLO contract checks + "
                    "repo-convention AST lint",
    )
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding (the CI gate)")
    ap.add_argument("--fast", action="store_true",
                    help="trim the program sweep to the fast arch + "
                         "TT/admission entries")
    ap.add_argument("--layer", choices=("all", "ast", "program"),
                    default="all")
    ap.add_argument("--rules", nargs="*", metavar="ID",
                    help="restrict to these rule IDs (e.g. AST001 PRG003)")
    ap.add_argument("--entries", nargs="*", metavar="SUBSTR",
                    help="restrict program entries by substring match")
    ap.add_argument("--root", default=".",
                    help="repo root for the AST layer (default: cwd)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    findings = run_checks(layer=args.layer, fast=args.fast,
                          rules=args.rules, entries=args.entries,
                          root=args.root)
    if args.as_json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        n_err = sum(f.rule_id == "ERROR" for f in findings)
        n = len(findings) - n_err
        status = "clean" if not findings else (
            f"{n} finding(s)" + (f", {n_err} analyzer error(s)" if n_err
                                 else ""))
        print(f"repro.analysis: {status} "
              f"(layer={args.layer}{', fast' if args.fast else ''})")
    if findings and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
