"""Two-layer invariant linter (see docs/ANALYSIS.md).

``python -m repro.analysis.check --strict`` is the CI gate: AST convention
rules (AST001–AST005) over ``src/`` plus traced program rules
(PRG001–PRG004) over the registered hot entry points.
"""

from repro.analysis.base import Finding, Rule, all_rules

__all__ = ["Finding", "Rule", "all_rules"]
