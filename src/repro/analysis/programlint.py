"""Layer 1: program lint over traced jaxprs and lowered/compiled HLO.

The registered hot entry points — the per-family fused decode scan, the
single decode step, the engine admission dispatches, and the TT-contraction
dispatch — are traced exactly as the serving stack jits them (same jit
wrappers, same donate/static argnums), then checked:

  PRG001  dtype discipline: no f64/c128 anywhere in the closed jaxpr or its
          lowering, and no weight-sized f32 closure constants (a TT core or
          dense bank silently materialized/upcast into the trace)
  PRG002  no host round-trips: no callback/infeed/outfeed/device_put
          primitives inside traced entry points (scan bodies included)
  PRG003  donation honored: every buffer engine.py marks donated shows
          input/output aliasing in the lowering (and, for the compiled
          representative, in the optimized HLO)
  PRG004  VMEM tile plans: every registered TT-contraction serving shape
          clears the fused kernels' VMEM gate at some candidate tile cap —
          sharing ``ops._fits_vmem`` so the gate and the lint can't diverge

Tracing is per-entry lazy: ``--fast`` covers one transformer arch plus the
TT/int8 variants and the admission paths; the full sweep adds every family
in the zoo (the CI lane runs full).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.analysis.base import Finding, Rule, register

PRG001 = register(Rule(
    "PRG001", "program", "f64 / weight-sized f32 const in traced entry",
    "hot entry points stay in bf16/f32 with no f64 promotion and no "
    "weight-sized float closure constants — an accidental upcast or dense "
    "materialization undoes the TT compression win without failing a test",
    guarded_since="PR 2 (TT dispatch), PR 7 (int8 cores)",
))
PRG002 = register(Rule(
    "PRG002", "program", "host callback/transfer in traced entry",
    "no callback / infeed / outfeed / device_put primitives inside the "
    "fused scan or admission dispatches — one host round-trip per step "
    "destroys the fused driver's dispatch amortization",
    guarded_since="PR 4 (fused decode driver)",
))
PRG003 = register(Rule(
    "PRG003", "program", "donation not honored",
    "buffers engine.py marks donated must show input/output aliasing in "
    "the lowering — dropped donation doubles the cache pool's memory and "
    "defeats in-place chunk updates",
    guarded_since="PR 5 (continuous batching engine)",
))
PRG004 = register(Rule(
    "PRG004", "program", "TT shape flunks the VMEM gate",
    "every registered TT-contraction serving shape must clear the fused "
    "kernels' VMEM gate at some candidate tile cap (shared _fits_vmem), "
    "or it silently rides the unfused fallback",
    guarded_since="PR 3 (fused TT kernels), PR 6 (adaptive tile caps)",
))

_BIG_CONST_ELEMS = 1 << 16      # weight-sized: ≥64Ki elements
_F64_LOWERED_RE = re.compile(r"[<x]f64\b")   # tensor<4xf64> / tensor<f64>


# --------------------------------------------------------------------------
# entry registry
# --------------------------------------------------------------------------

@dataclasses.dataclass
class EntryReport:
    """Artifacts of one traced entry point."""

    name: str
    jaxpr: object                      # jax.core.ClosedJaxpr
    lowered: Optional[str]             # StableHLO text
    compiled: Optional[str]            # optimized HLO text (representative)
    donated: bool                      # engine marks a donated argument


FAST_ARCH = "qwen1.5-0.5b"
FAMILY_ARCHS = (
    "gemma3-1b",              # transformer (dense)
    "seamless-m4t-large-v2",  # encdec
    "mamba2-1.3b",            # ssm
    "recurrentgemma-2b",      # hybrid
    "olmoe-1b-7b",            # moe expert banks
)


def _reduced(arch: str, weights: str = "dense"):
    from repro.configs import get_config
    from repro.models.registry import build
    import jax

    cfg = get_config(arch).reduced()
    model = build(cfg)
    if weights == "dense":
        return cfg, model, model.init(jax.random.PRNGKey(0))
    from repro.core import CompressionPolicy, TTCompressor, spectral_decay_pytree
    from repro.models import common as model_common
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=0.2, min_size=8192))
    payload, _ = comp.compress(params)
    quant = "int8" if weights == "tt-int8" else None
    return cfg, model, model_common.tt_native_params(
        payload, family=cfg.family, quant=quant)


def _trace_gen_scan(arch: str, weights: str, compile_entry: bool) -> EntryReport:
    """The fused chunk dispatch exactly as the engine jits it
    (``engine._run_steps``: static decode/steps/sampling, donated state)."""
    import jax.numpy as jnp
    from repro.launch import engine
    from repro.models import common as model_common

    cfg, model, params = _reduced(arch, weights)
    b, t_max, plen = 2, 10, 4
    tokens = np.zeros((b, t_max), np.int32)
    state = model_common.gen_init(
        model.init_cache(b, t_max), tokens, plen, t_max,
        cfg.padded_vocab_size, rng=jnp.zeros((b, 2), jnp.uint32),
    )
    tr = engine._run_steps.trace(
        model.decode_step, params, state, 3, model_common.GREEDY)
    low = tr.lower()
    compiled = low.compile().as_text() if compile_entry else None
    suffix = "" if weights == "dense" else f"-{weights}"
    return EntryReport(f"gen_scan/{arch}{suffix}", tr.jaxpr,
                       low.as_text(), compiled, donated=True)


def _trace_decode_step(arch: str) -> EntryReport:
    """One decode step as the python-loop oracle jits it
    (``engine._decode_fn``: donated cache)."""
    import jax.numpy as jnp
    from repro.launch import engine

    _, model, params = _reduced(arch)
    cache = model.init_cache(2, 10)
    tr = engine._decode_fn(model).trace(
        params, cache, jnp.zeros((2, 1), jnp.int32))
    return EntryReport(f"decode_step/{arch}", tr.jaxpr,
                       tr.lower().as_text(), None, donated=True)


def _admission_entries(arch: str) -> Iterator[EntryReport]:
    """The engine's donated admission dispatches against a live engine
    state (scan admission: queue + done buffer attached)."""
    import jax.numpy as jnp
    from repro.launch.engine import (
        Engine, _admit_slot, _deactivate_slot, _refill_scan,
    )

    _, model, params = _reduced(arch)
    eng = Engine(model, params, slots=2, max_len=10, chunk_steps=2,
                 admission="scan")
    state = eng.state
    row = jnp.zeros((eng.max_len,), jnp.int32)
    key = jnp.zeros((2,), jnp.uint32)
    tr = _admit_slot.trace(state, 0, row, 3, 8, key,
                           jnp.float32(0.0), jnp.int32(0))
    yield EntryReport("admit/_admit_slot", tr.jaxpr, tr.lower().as_text(),
                      None, donated=True)
    tr = _deactivate_slot.trace(state, 0)
    yield EntryReport("admit/_deactivate_slot", tr.jaxpr,
                      tr.lower().as_text(), None, donated=True)
    q = state.queue
    tr = _refill_scan.trace(state, q.tokens, q.prompt_len, q.total_len,
                            q.rng, q.temp, q.topk, q.size)
    yield EntryReport("admit/_refill_scan", tr.jaxpr, tr.lower().as_text(),
                      None, donated=True)


def _trace_tt_contract(shape: "TTShape") -> EntryReport:
    """The TT-contraction dispatch at a registered serving shape (fused
    path: the VMEM gate must pass, see PRG004)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.tt_contract import ops

    x2 = jnp.zeros((shape.b, shape.n_in()), jnp.float32)
    cores = [jnp.zeros(s, _np_dtype(dt))
             for s, dt in zip(shape.cores, shape.dtypes)]
    scales = ([jnp.float32(1.0) if dt == "int8" else None
               for dt in shape.dtypes]
              if any(dt == "int8" for dt in shape.dtypes) else None)

    def run(x2, *cores):
        return ops.tt_contract(x2, cores, shape.split, scales=scales)

    tr = jax.jit(run).trace(x2, *cores)
    return EntryReport(f"tt_contract/{shape.name}", tr.jaxpr,
                       tr.lower().as_text(), None, donated=False)


def iter_entries(fast: bool = False
                 ) -> Iterator[Tuple[str, Callable[[], EntryReport]]]:
    """(name, lazy builder) for every registered entry point.

    The builder defers the expensive init/trace until the runner asks, so
    rule filtering and ``--fast`` skip work they don't need.
    """
    archs = (FAST_ARCH,) if fast else (FAST_ARCH,) + FAMILY_ARCHS
    for i, arch in enumerate(archs):
        # compile exactly one representative (the cheap fast arch) to check
        # aliasing survives XLA optimization, not just lowering
        yield (f"gen_scan/{arch}",
               lambda a=arch, c=(i == 0): _trace_gen_scan(a, "dense", c))
    yield (f"gen_scan/{FAST_ARCH}-tt",
           lambda: _trace_gen_scan(FAST_ARCH, "tt", False))
    if not fast:
        yield (f"gen_scan/{FAST_ARCH}-tt-int8",
               lambda: _trace_gen_scan(FAST_ARCH, "tt-int8", False))
    yield (f"decode_step/{FAST_ARCH}",
           lambda: _trace_decode_step(FAST_ARCH))
    yield ("admission", lambda: list(_admission_entries(FAST_ARCH)))
    for shape in REGISTERED_TT_SHAPES[: 2 if fast else None]:
        yield (f"tt_contract/{shape.name}",
               lambda s=shape: _trace_tt_contract(s))


# --------------------------------------------------------------------------
# PRG004 — registered TT serving shapes vs the shared VMEM gate
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TTShape:
    """One lead-absorbed TT chain shape the serving stack dispatches."""

    name: str
    b: int                              # flattened batch·token extent
    cores: Tuple[Tuple[int, ...], ...]  # [(n1, r1), (r, n, s)...], last s==1
    split: int
    dtypes: Tuple[str, ...]             # per-core storage dtype

    def n_in(self) -> int:
        # split counts input modes: the lead core's n1 plus the middle mode
        # of each further input-side core (see kernels/tt_contract/ref.py)
        ins = self.cores[0][0]
        for s in self.cores[1:self.split]:
            ins *= s[1]
        return ins

    def n_out(self) -> int:
        out = 1
        for s in self.cores[self.split:]:
            out *= s[1]
        return out


def _shape2(name, b, n1, r1, n2, dtypes=("f32", "f32")):
    return TTShape(name, b, ((n1, r1), (r1, n2, 1)), 1, dtypes)


REGISTERED_TT_SHAPES: Tuple[TTShape, ...] = (
    # decode-extent (B = slots) and prefill-extent (B = tokens) chains at
    # full-size factorizations; int8 variants store cores at 1 byte/elem
    _shape2("decode-2core", 8, 1152, 64, 4608),
    _shape2("prefill-2core", 2048, 1024, 48, 4096),
    _shape2("prefill-2core-int8", 2048, 1024, 48, 4096, ("int8", "int8")),
    TTShape("decode-3core-split1", 8,
            ((64, 48), (48, 32, 24), (24, 72, 1)), 1,
            ("f32", "f32", "f32")),
    TTShape("prefill-3core-split2", 1024,
            ((64, 32), (32, 32, 16), (16, 96, 1)), 2,
            ("f32", "f32", "f32")),
    TTShape("expert-tile-3core-int8", 128,
            ((512, 32), (32, 64, 16), (16, 32, 1)), 2,
            ("int8", "int8", "int8")),
)


def _np_dtype(name: str):
    return {"f32": np.float32, "bf16": np.float32, "int8": np.int8}[name]


def check_vmem_shapes(shapes: Sequence[TTShape] = REGISTERED_TT_SHAPES,
                      ) -> List[Finding]:
    """PRG004: each registered shape must clear ``ops._fits_vmem`` at some
    candidate cap from ``resolve_tile_cap`` — the exact dispatch loop."""
    from repro.kernels.tt_contract import ops

    findings = []
    for shape in shapes:
        x2 = np.zeros((shape.b, shape.n_in()), np.float32)
        cores = [np.zeros(s, _np_dtype(dt))
                 for s, dt in zip(shape.cores, shape.dtypes)]
        caps = ops.resolve_tile_cap(shape.b)
        fit = next((c for c in caps
                    if ops._fits_vmem(x2, cores, shape.n_out(), shape.split,
                                      c)), None)
        if fit is None:
            findings.append(Finding(
                "PRG004", f"entry:tt_contract/{shape.name}", 0,
                f"no candidate tile cap {tuple(caps)} fits the VMEM budget "
                f"for cores {shape.cores} at B={shape.b} — this registered "
                f"serving shape would silently ride the unfused fallback",
            ))
    return findings


# --------------------------------------------------------------------------
# jaxpr / HLO checks
# --------------------------------------------------------------------------

def _iter_jaxprs(jaxpr) -> Iterator[object]:
    """The jaxpr and every sub-jaxpr reachable through eqn params (scan
    bodies, cond branches, pjit calls, custom_vjp closures, ...)."""
    from jax._src import core as jcore

    seen = set()
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        if isinstance(j, jcore.ClosedJaxpr):
            j = j.jaxpr
        if not isinstance(j, jcore.Jaxpr) or id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(sub, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                        stack.append(sub)


def _check_dtypes(rep: EntryReport) -> List[Finding]:
    findings = []
    flagged: Set[str] = set()
    for j in _iter_jaxprs(rep.jaxpr):
        for eqn in j.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(var, "aval", None)
                dt = str(getattr(aval, "dtype", ""))
                if dt in ("float64", "complex128") and dt not in flagged:
                    flagged.add(dt)
                    findings.append(Finding(
                        "PRG001", f"entry:{rep.name}", 0,
                        f"{dt} value in primitive {eqn.primitive.name!r} — "
                        f"double precision in a hot entry point (x64 leak?)",
                    ))
    consts = getattr(rep.jaxpr, "consts", ()) or ()
    for c in consts:
        dt = str(getattr(c, "dtype", ""))
        size = int(getattr(c, "size", 0) or 0)
        if dt in ("float32", "float64") and size >= _BIG_CONST_ELEMS:
            findings.append(Finding(
                "PRG001", f"entry:{rep.name}", 0,
                f"weight-sized {dt} constant ({size} elems) closed over the "
                f"trace — a TT core or weight bank materialized/upcast into "
                f"the program instead of riding as a compressed argument",
            ))
    if rep.lowered and _F64_LOWERED_RE.search(rep.lowered):
        findings.append(Finding(
            "PRG001", f"entry:{rep.name}", 0,
            "f64 tensor type in the lowered StableHLO",
        ))
    if rep.compiled:
        from repro.roofline import hlo_walk
        f64 = {dt for dt, _ in hlo_walk.iter_shapes(rep.compiled)
               if dt in ("f64", "c128")}
        if f64:
            findings.append(Finding(
                "PRG001", f"entry:{rep.name}", 0,
                f"{sorted(f64)} buffers in the optimized HLO",
            ))
    return findings


_BANNED_PRIMS = {"infeed", "outfeed", "device_put"}


def _check_callbacks(rep: EntryReport) -> List[Finding]:
    findings = []
    flagged: Set[str] = set()
    for j in _iter_jaxprs(rep.jaxpr):
        for eqn in j.eqns:
            name = eqn.primitive.name
            if (name in _BANNED_PRIMS or "callback" in name) \
                    and name not in flagged:
                flagged.add(name)
                findings.append(Finding(
                    "PRG002", f"entry:{rep.name}", 0,
                    f"host primitive {name!r} inside a traced entry point — "
                    f"a host round-trip per step defeats the fused driver",
                ))
    return findings


def _check_donation(rep: EntryReport) -> List[Finding]:
    if not rep.donated:
        return []
    findings = []
    if rep.lowered is not None and "tf.aliasing_output" not in rep.lowered:
        findings.append(Finding(
            "PRG003", f"entry:{rep.name}", 0,
            "entry is marked donated but its lowering carries no "
            "tf.aliasing_output attribute — donation was dropped (shape/"
            "dtype mismatch between the donated operand and any output?)",
        ))
    if rep.compiled is not None and "input_output_alias" not in rep.compiled:
        findings.append(Finding(
            "PRG003", f"entry:{rep.name}", 0,
            "optimized HLO carries no input_output_alias — XLA discarded "
            "the donation",
        ))
    return findings


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

_CHECKS = (_check_dtypes, _check_callbacks, _check_donation)


def run(fast: bool = False, rules: Optional[Set[str]] = None,
        entries: Optional[Sequence[str]] = None) -> List[Finding]:
    """Trace the registered entries and apply PRG001–PRG004.

    ``rules`` restricts rule IDs; ``entries`` restricts entry names by
    substring match.  A build/trace failure is itself reported (rule
    ``ERROR``) so one broken family can't silently mask the rest.
    """
    want = rules or {"PRG001", "PRG002", "PRG003", "PRG004"}
    findings: List[Finding] = []
    need_trace = want & {"PRG001", "PRG002", "PRG003"}
    if need_trace:
        for name, build in iter_entries(fast):
            if entries and not any(e in name for e in entries):
                continue
            try:
                built = build()
            except Exception as e:  # noqa: BLE001 - surfaced as a finding
                findings.append(Finding(
                    "ERROR", f"entry:{name}", 0,
                    f"failed to build/trace: {type(e).__name__}: {e}"))
                continue
            reports = built if isinstance(built, list) else [built]
            for rep in reports:
                for check, rid in zip(_CHECKS,
                                      ("PRG001", "PRG002", "PRG003")):
                    if rid in want:
                        findings.extend(check(rep))
    if "PRG004" in want:
        findings.extend(check_vmem_shapes())
    return findings
