"""Layer 2: AST convention lint over ``src/``.

Every rule here encodes a convention that keeps the TT/quant dispatch and
the serving runtime honest — things an ordinary test suite can't see
because bypassing them still computes the right numbers, just without the
compression/perf win (or with a latent race).  Rules:

  AST001  weight matmuls in ``models/`` route through dense_apply/expert_apply
  AST002  no wall-clock / global numpy RNG in device-code modules
  AST003  Router mailbox mutation only under the router lock
  AST004  every kernels/<name>/ package ships kernel.py + ref.py + ops.py
          and a parity test under tests/
  AST005  skip markers must name known rule IDs

Suppression: ``# lint: skip[AST001]`` on the flagged line or on a
comment line directly above it (see ``base.skip_markers``).
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set

from repro.analysis.base import (
    Finding, Rule, register, skip_markers, suppressed,
)

AST001 = register(Rule(
    "AST001", "ast", "weight matmul bypasses dispatch",
    "weight-shaped einsum/@/dot in models/ must go through "
    "dense_apply/expert_apply — the raw-vs-TT-vs-int8 dispatch point",
    guarded_since="PR 2 (TT dispatch), PR 7 (int8 cores)",
))
AST002 = register(Rule(
    "AST002", "ast", "host nondeterminism in device code",
    "models/kernels/core modules must not call time.time()-style clocks or "
    "the global numpy RNG (seeded RandomState/default_rng constructors are "
    "fine) — traced code must be replayable",
    guarded_since="PR 4 (fused decode driver)",
))
AST003 = register(Rule(
    "AST003", "ast", "mailbox mutation outside router lock",
    "replica mailbox operations (.commands submit-put / get_nowait drain / "
    "reassignment) must sit lexically under `with <...lock>` — the failover "
    "path re-queues in-flight commands and must never race a submit",
    guarded_since="PR 8 (fault-tolerant serving)",
))
AST004 = register(Rule(
    "AST004", "ast", "kernel package missing ref oracle or parity test",
    "every kernels/<name>/ package ships kernel.py + ref.py + ops.py and is "
    "named by a parity test under tests/ — fused paths never exist without "
    "an oracle",
    guarded_since="PR 2 (kernel package layout)",
))
AST005 = register(Rule(
    "AST005", "ast", "skip marker names unknown rule",
    "`# lint: skip[...]` markers must name registered rule IDs — stale or "
    "misspelled suppressions are findings, not silence",
    guarded_since="PR 9 (this linter)",
))

# --------------------------------------------------------------------------
# AST001 — weight matmuls must route through dense_apply / expert_apply
# --------------------------------------------------------------------------

# Identifier roots that look like weights/parameter banks.  Tuned against
# the current models/ tree: params fields (w_gate, wg, wu, wd, router,
# conv_w, embed, cores, lead) match; activations (x, h, logits, sent, hist,
# qg, mix_ij, ...) don't.
_WEIGHT_NAME = re.compile(
    r"^(w|w[a-z0-9]|w_[a-z0-9_]+|\w*weights?\w*|router\w*|embed\w*|"
    r"kernel|conv_w|cores?|lead\w*|tables?)$"
)

# einsum/matmul/dot spellings on the numpy/lax namespaces, plus the `@`
# operator (handled separately as BinOp MatMult).
_MATMUL_FNS = {"einsum", "matmul", "tensordot", "dot", "vdot", "dot_general"}
_MATMUL_NAMESPACES = {"jnp", "np", "numpy", "lax", "jax"}

# Calls defined inside these functions ARE the dispatch point.
_DISPATCH_FNS = {"dense_apply", "expert_apply"}


def _dotted(node: ast.AST) -> Optional[str]:
    """'jnp.einsum' / 'jax.lax.dot_general' for an Attribute/Name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_matmul_call(call: ast.Call) -> bool:
    name = _dotted(call.func)
    if name is None:
        return False
    parts = name.split(".")
    return (parts[-1] in _MATMUL_FNS and parts[0] in _MATMUL_NAMESPACES
            and len(parts) >= 2)


def _operand_roots(node: ast.AST) -> Iterator[str]:
    """Identifier roots of an operand expression, unwrapping method calls
    (``w.astype(f32)``), subscripts (``bank[i]``), and binary ops."""
    if isinstance(node, ast.Attribute):
        yield node.attr
    elif isinstance(node, ast.Name):
        yield node.id
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        yield from _operand_roots(node.func.value)
    elif isinstance(node, ast.Subscript):
        yield from _operand_roots(node.value)
    elif isinstance(node, ast.BinOp):
        yield from _operand_roots(node.left)
        yield from _operand_roots(node.right)
    elif isinstance(node, ast.UnaryOp):
        yield from _operand_roots(node.operand)


def _weight_roots(operands: Sequence[ast.AST]) -> List[str]:
    return [r for op in operands for r in _operand_roots(op)
            if _WEIGHT_NAME.match(r)]


class _Ast001Visitor(ast.NodeVisitor):
    def __init__(self, path: str, skips):
        self.path, self.skips = path, skips
        self.fn_stack: List[str] = []
        self.findings: List[Finding] = []

    def _in_dispatch(self) -> bool:
        return any(fn in _DISPATCH_FNS for fn in self.fn_stack)

    def visit_FunctionDef(self, node):
        self.fn_stack.append(node.name)
        self.generic_visit(node)
        self.fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _flag(self, node: ast.AST, roots: List[str]):
        if self._in_dispatch():
            return
        if suppressed(self.skips, "AST001", node.lineno,
                      getattr(node, "end_lineno", None)):
            return
        self.findings.append(Finding(
            "AST001", self.path, node.lineno,
            f"weight-shaped matmul on {sorted(set(roots))} bypasses "
            f"dense_apply/expert_apply (the raw/TT/int8 dispatch point); "
            f"route through models.common or justify with "
            f"`# lint: skip[AST001]`",
        ))

    def visit_Call(self, node: ast.Call):
        if _is_matmul_call(node):
            name = _dotted(node.func) or ""
            # einsum's first positional arg is the spec string
            operands = node.args[1:] if name.endswith("einsum") else node.args
            roots = _weight_roots(operands)
            if roots:
                self._flag(node, roots)
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp):
        if isinstance(node.op, ast.MatMult):
            roots = _weight_roots([node.left, node.right])
            if roots:
                self._flag(node, roots)
        self.generic_visit(node)


# --------------------------------------------------------------------------
# AST002 — no wall clock / global RNG in device-code modules
# --------------------------------------------------------------------------

_CLOCK_FNS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
              "monotonic", "monotonic_ns", "process_time"}
# np.random attributes that are NOT global-state draws (seeded constructors
# and types) — everything else on np.random is the module-global stream.
_NP_RANDOM_OK = {"RandomState", "default_rng", "Generator", "SeedSequence",
                 "PCG64", "Philox", "BitGenerator", "MT19937"}


class _Ast002Visitor(ast.NodeVisitor):
    def __init__(self, path: str, skips):
        self.path, self.skips = path, skips
        self.findings: List[Finding] = []
        self.time_aliases: Set[str] = set()   # from time import time, ...

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module == "time":
            for alias in node.names:
                if alias.name in _CLOCK_FNS:
                    self.time_aliases.add(alias.asname or alias.name)
        self.generic_visit(node)

    def _flag(self, node, what: str):
        if suppressed(self.skips, "AST002", node.lineno,
                      getattr(node, "end_lineno", None)):
            return
        self.findings.append(Finding(
            "AST002", self.path, node.lineno,
            f"{what} in a device-code module — traced/benchmarked code must "
            f"be deterministic and replayable; take timestamps in launch/ "
            f"or thread a seeded generator through",
        ))

    def visit_Call(self, node: ast.Call):
        func = node.func
        name = _dotted(func)
        if name is not None:
            parts = name.split(".")
            if (len(parts) == 2 and parts[0] == "time"
                    and parts[1] in _CLOCK_FNS):
                self._flag(node, f"wall-clock call {name}()")
            elif (len(parts) >= 3 and parts[-3] in ("np", "numpy")
                    and parts[-2] == "random"
                    and parts[-1] not in _NP_RANDOM_OK):
                self._flag(node, f"global numpy RNG call {name}()")
            elif len(parts) == 1 and parts[0] in self.time_aliases:
                self._flag(node, f"wall-clock call {name}()")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# AST003 — Router mailbox mutation only under the router lock
# --------------------------------------------------------------------------
#
# The mailbox contract (launch/router.py): submit-side puts, drain-side
# get_nowait() sweeps, and mailbox replacement happen under self._lock so
# failover can atomically re-queue in-flight commands.  Exempt by design:
#   * nudge puts — `put(None)` or `put(("nudge", ...))` — which only wake a
#     worker; a lost or duplicated nudge is harmless,
#   * the worker's blocking `.get(timeout=...)` (single consumer),
#   * construction inside __init__ (no concurrent reader yet).


def _mentions_lock(node: ast.AST) -> bool:
    return any(
        "lock" in part.lower()
        for n in ast.walk(node)
        for part in ([n.attr] if isinstance(n, ast.Attribute)
                     else [n.id] if isinstance(n, ast.Name) else [])
    )


class _Ast003Visitor(ast.NodeVisitor):
    def __init__(self, path: str, skips):
        self.path, self.skips = path, skips
        self.findings: List[Finding] = []
        self.lock_depth = 0
        self.fn_stack: List[str] = []

    def visit_With(self, node: ast.With):
        locked = any(_mentions_lock(item.context_expr)
                     for item in node.items)
        self.lock_depth += locked
        self.generic_visit(node)
        self.lock_depth -= locked

    def visit_FunctionDef(self, node):
        self.fn_stack.append(node.name)
        self.generic_visit(node)
        self.fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _flag(self, node, what: str):
        if suppressed(self.skips, "AST003", node.lineno,
                      getattr(node, "end_lineno", None)):
            return
        self.findings.append(Finding(
            "AST003", self.path, node.lineno,
            f"{what} outside `with <lock>` — mailbox mutation must be "
            f"atomic with failover's re-queue sweep",
        ))

    def visit_Call(self, node: ast.Call):
        func = node.func
        if (isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Attribute)
                and func.value.attr == "commands"
                and self.lock_depth == 0):
            if func.attr == "put":
                args = node.args
                nudge = len(args) == 1 and (
                    (isinstance(args[0], ast.Constant)
                     and args[0].value is None)
                    or (isinstance(args[0], ast.Tuple) and args[0].elts
                        and isinstance(args[0].elts[0], ast.Constant)
                        and args[0].elts[0].value == "nudge"))
                if not nudge:
                    self._flag(node, "mailbox .commands.put(<command>)")
            elif func.attr == "get_nowait":
                self._flag(node, "mailbox .commands.get_nowait() drain")
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        if self.lock_depth == 0 and "__init__" not in self.fn_stack:
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and tgt.attr == "commands":
                    self._flag(node, "mailbox replacement (.commands = ...)")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# AST004 — kernel package completeness
# --------------------------------------------------------------------------

_KERNEL_REQUIRED = ("kernel.py", "ref.py", "ops.py")


def _check_kernel_packages(root: Path) -> List[Finding]:
    findings: List[Finding] = []
    kdir = root / "src" / "repro" / "kernels"
    if not kdir.is_dir():
        return findings
    test_text = "".join(
        p.read_text(encoding="utf-8") for p in sorted((root / "tests").glob("*.py"))
    ) if (root / "tests").is_dir() else ""
    for pkg in sorted(p for p in kdir.iterdir() if p.is_dir()):
        if pkg.name.startswith(("_", ".")):
            continue
        rel = pkg.relative_to(root).as_posix()
        for req in _KERNEL_REQUIRED:
            if not (pkg / req).is_file():
                findings.append(Finding(
                    "AST004", rel, 0,
                    f"kernel package is missing {req} — fused kernels ship "
                    f"with a reference oracle and a dispatch wrapper",
                ))
        if (f"kernels.{pkg.name}" not in test_text
                and f"kernels/{pkg.name}" not in test_text):
            findings.append(Finding(
                "AST004", rel, 0,
                f"no test under tests/ references kernels.{pkg.name} — "
                f"every fused path needs a kernel-vs-ref parity test",
            ))
    return findings


# --------------------------------------------------------------------------
# AST005 — skip-marker hygiene
# --------------------------------------------------------------------------


def _check_markers(path: str, skips: Dict[int, Set[str]],
                   known: Set[str]) -> List[Finding]:
    findings = []
    for lineno in sorted(skips):
        for rid in sorted(skips[lineno] - known):
            findings.append(Finding(
                "AST005", path, lineno,
                f"skip marker names unknown rule {rid!r} — registered rules: "
                f"{sorted(known)}",
            ))
    return findings


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

_SCOPE_AST001 = ("src/repro/models/",)
_SCOPE_AST002 = ("src/repro/models/", "src/repro/kernels/", "src/repro/core/")


def run(root, rules: Optional[Set[str]] = None) -> List[Finding]:
    """Run the AST rules over ``root`` (a repo checkout with ``src/repro``).

    ``rules`` restricts to a subset of rule IDs (default: all AST rules).
    """
    root = Path(root)
    want = rules or {"AST001", "AST002", "AST003", "AST004", "AST005"}
    known = {"AST001", "AST002", "AST003", "AST004", "AST005",
             "PRG001", "PRG002", "PRG003", "PRG004"}
    findings: List[Finding] = []
    src = root / "src" / "repro"
    for py in sorted(src.rglob("*.py")):
        rel = py.relative_to(root).as_posix()
        source = py.read_text(encoding="utf-8")
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError as e:
            findings.append(Finding(
                "AST005", rel, e.lineno or 0, f"unparseable module: {e.msg}"))
            continue
        skips = skip_markers(source)
        if "AST001" in want and rel.startswith(_SCOPE_AST001):
            v = _Ast001Visitor(rel, skips)
            v.visit(tree)
            findings.extend(v.findings)
        if "AST002" in want and rel.startswith(_SCOPE_AST002):
            v = _Ast002Visitor(rel, skips)
            v.visit(tree)
            findings.extend(v.findings)
        if "AST003" in want:
            v = _Ast003Visitor(rel, skips)
            v.visit(tree)
            findings.extend(v.findings)
        if "AST005" in want:
            findings.extend(_check_markers(rel, skips, known))
    if "AST004" in want:
        findings.extend(_check_kernel_packages(root))
    return findings
