"""MLP blocks: gated (SwiGLU/GeGLU) dense FFN and expert-parallel MoE.

MoE uses gather-based token grouping with static expert capacity (dropless
up to the capacity factor), experts sharded over the ``model`` axis —
dispatch/combine are all-to-all-shaped collectives under GSPMD.  Cost is
linear in tokens (no GShard one-hot dispatch einsum, which would be
quadratic at 32k prefill).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.models import common


class MLPParams(NamedTuple):
    w_gate: jax.Array             # (D, F)
    w_up: jax.Array               # (D, F)
    w_down: jax.Array             # (F, D)


def init_mlp(key, cfg, d_ff: Optional[int] = None,
             layers: Optional[int] = None) -> MLPParams:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = common.cdtype(cfg)
    ks = jax.random.split(key, 3)

    def mk(shape, k, in_axis=0):
        if layers is None:
            return common.dense_init(k, shape, in_axis, dt)
        return jax.vmap(
            lambda kk: common.dense_init(kk, shape, in_axis, dt)
        )(jax.random.split(k, layers))

    return MLPParams(
        w_gate=mk((d, f), ks[0]),
        w_up=mk((d, f), ks[1]),
        w_down=mk((f, d), ks[2]),
    )


def mlp_apply(x: jax.Array, p: MLPParams, act: str) -> jax.Array:
    """Gated FFN; weights may be raw arrays or TT payloads — every matmul
    goes through the ``common.dense_apply`` dispatch point."""
    g = common.activate(common.dense_apply(x, p.w_gate), act)
    u = common.dense_apply(x, p.w_up)
    return common.dense_apply(g * u, p.w_down)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

class MoEParams(NamedTuple):
    router: jax.Array             # (D, E)
    w_gate: jax.Array             # (E, D, F)
    w_up: jax.Array               # (E, D, F)
    w_down: jax.Array             # (E, F, D)


def init_moe(key, cfg, layers: Optional[int] = None) -> MoEParams:
    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_ff
    dt = common.cdtype(cfg)
    ks = jax.random.split(key, 4)

    def mk(shape, k, in_axis):
        def one(kk):
            return common.dense_init(kk, shape, in_axis, dt)
        if layers is None:
            return one(k)
        return jax.vmap(one)(jax.random.split(k, layers))

    return MoEParams(
        router=mk((d, e), ks[0], 0),
        w_gate=mk((e, d, f), ks[1], 1),
        w_up=mk((e, d, f), ks[2], 1),
        w_down=mk((e, f, d), ks[3], 1),
    )


def _route_and_fill(xf, router, e, k, cap, dtype):
    """Router + slot assignment + scatter into per-expert buffers.

    xf: (n, d) tokens (global on the GSPMD path, LOCAL inside shard_map).
    Returns buf (e·cap, d), slot (n·k,), keep (n·k,), topk_p (n, k).
    """
    n, d = xf.shape
    logits = common.dense_apply(xf.astype(jnp.float32),
                                router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, k)             # (n, k)
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # slot assignment: for each (token, k) pair, its rank among same-expert
    # assignments (capacity dropping = rank >= cap)
    flat_e = topk_e.reshape(-1)                          # (n*k,)
    order = jnp.argsort(flat_e, stable=True)             # group by expert
    ranks_sorted = jnp.arange(n * k) - jnp.searchsorted(
        flat_e[order], flat_e[order], side="left"
    )
    inv = jnp.argsort(order)
    rank = ranks_sorted[inv]                             # (n*k,) rank in expert
    keep = rank < cap
    slot = flat_e * cap + jnp.minimum(rank, cap - 1)     # (n*k,) target slot

    buf = jnp.zeros((e * cap, d), dtype)
    src = jnp.repeat(xf, k, axis=0)                      # token for each slot
    buf = buf.at[slot].add(jnp.where(keep[:, None], src, 0))
    return buf, slot, keep, topk_p


def _moe_a2a_applicable(cfg, b, s_len):
    from repro.launch import sharding as _shd
    mesh = _shd.current_mesh()
    if mesh is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dp = 1
    for a in batch_axes:
        dp *= sizes[a]
    if m <= 1 or cfg.moe.num_experts % m or b % max(dp, 1):
        return None
    if ((b // dp) * s_len) % m:          # decode-sized token rows: fall back
        return None
    return mesh, m, batch_axes, dp


def moe_apply_a2a(x, p: MoEParams, cfg, capacity_factor: float = 1.25):
    """Expert-parallel MoE with an EXPLICIT all-to-all dispatch (shard_map).

    The GSPMD scatter formulation partial-sums (cap, d_ff) activations over
    the model axis (measured: EXPERIMENTS.md §Perf cell 2).  Here tokens are
    routed LOCALLY per device, exchanged with one all-to-all over the model
    axis into expert-major layout, FFN'd expert-locally, and returned by the
    inverse all-to-all — the textbook EP schedule, stated manually because
    GSPMD cannot infer it through the scatter.
    """
    import functools as _ft
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as _shd

    b, s_len, d = x.shape
    e = cfg.moe.num_experts
    k = cfg.moe.num_experts_per_tok
    app = _moe_a2a_applicable(cfg, b, s_len)
    assert app is not None
    mesh, m, batch_axes, dp = app
    e_loc = e // m
    f_ax = "data" if cfg.fsdp else None

    n_row = (b // dp) * s_len              # tokens per data row (model-repl.)
    assert n_row % m == 0, (n_row, m)
    n_loc = n_row // m                     # distinct tokens per model peer
    cap_loc = max(min(int(np.ceil(n_loc * k / e * capacity_factor)), n_loc), 1)

    def body(xl, router, wg, wu, wd):
        # xl (B_loc, S, D) is REPLICATED across the model axis — each model
        # peer takes its own 1/M token slice so no work is duplicated.
        if cfg.fsdp:
            router = jax.lax.all_gather(router, "data", axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        midx = jax.lax.axis_index("model")
        xf = jax.lax.dynamic_slice_in_dim(
            xl.reshape(-1, d), midx * n_loc, n_loc, axis=0)
        buf, slot, keep, topk_p = _route_and_fill(
            xf, router, e, k, cap_loc, xl.dtype)

        # dispatch: (E, cap_loc, D) -> (E_loc, M*cap_loc, D) over 'model'
        sent = jax.lax.all_to_all(
            buf.reshape(e, cap_loc, d), "model", 0, 1, tiled=True
        ).reshape(e_loc, m * cap_loc, d)

        g = common.activate(common.expert_apply(sent, wg), cfg.act)
        u = common.expert_apply(sent, wu)
        out = common.expert_apply(g * u, wd)             # (E_loc, M·cap, D)

        # return: inverse all-to-all back to token-major layout.  out's
        # second axis is peer-major ([peer0 cap | peer1 cap | …]) — put the
        # peer axis first so each peer gets its own experts back, and the
        # receive-concat is expert-major (matching slot = e·cap + rank).
        ret = jax.lax.all_to_all(
            out.reshape(e_loc, m, cap_loc, d)
               .transpose(1, 0, 2, 3).reshape(m * e_loc, cap_loc, d),
            "model", 0, 0, tiled=True
        ).reshape(e * cap_loc, d)

        per_slot = ret[slot]
        w = (topk_p.reshape(-1) * keep).astype(jnp.float32)[:, None]
        combined = (per_slot.astype(jnp.float32) * w).reshape(
            n_loc, k, d).sum(1)
        # restore the model-replicated token layout
        full = jax.lax.all_gather(
            combined.astype(xl.dtype), "model", axis=0, tiled=True)
        return full.reshape(xl.shape)

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0]
                                                    if batch_axes else None)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None),
                  P(f_ax, None),
                  P("model", f_ax, None),
                  P("model", f_ax, None),
                  P("model", None, f_ax)),
        out_specs=P(bspec, None, None),
    )(x, p.router, p.w_gate, p.w_up, p.w_down)


def moe_apply(
    x: jax.Array,                # (B, S, D)
    p: MoEParams,
    cfg,
    capacity_factor: float = 1.25,
) -> jax.Array:
    """Top-k routing with static per-expert capacity; gather/scatter grouping.

    Returns the combined expert outputs (B, S, D).  Aux-free (loss-side
    z-loss/load-balance handled by the trainer; see train/losses.py).

    NOTE (serving): capacity COUPLES batch rows — a token's slot rank, and
    hence whether it is dropped, depends on the other rows routed with it.
    The fused decode driver is still token-for-token identical to the
    python loop (same batch, same routing), but continuous batching cannot
    promise staggered == isolated for MoE the way it does for every other
    family: a slot's neighbours (including retired slots' frozen lockstep
    tokens) legitimately shift expert capacity.
    """
    b, s, d = x.shape
    e = cfg.moe.num_experts
    k = cfg.moe.num_experts_per_tok
    if getattr(cfg, "opt_moe_a2a", False) and \
            not common.layers_have_tt(p) and \
            _moe_a2a_applicable(cfg, b, s) is not None:
        # a2a shard_maps the raw expert arrays; TT-native banks (serving)
        # take the expert-batched chain below instead
        return moe_apply_a2a(x, p, cfg, capacity_factor)
    n = b * s
    cap = int(np.ceil(n * k / e * capacity_factor))
    cap = max(min(cap, n), 1)

    xf = x.reshape(n, d)
    buf, slot, keep, topk_p = _route_and_fill(xf, p.router, e, k, cap, x.dtype)

    # expert FFN on grouped tokens: (E, cap, D) einsum with expert weights
    if getattr(cfg, "opt_moe_ep", False):
        # pin the pre-dispatch layout too: slots over data, d replicated —
        # the (data→model) reshard into the expert layout below is then a
        # clean all-to-all instead of whatever GSPMD propagates backwards
        # through the scatter.
        from repro.launch import sharding as _shd
        buf = _shd.act_constraint(buf, "data", None)
    h = buf.reshape(e, cap, d)
    if getattr(cfg, "opt_moe_ep", False):
        # §Perf hillclimb (dbrx): pin the expert-parallel layout — dispatch
        # becomes one all-to-all of (E, cap, D) tokens and every FFN matmul
        # is expert-local, instead of GSPMD's partial-sum all-reduce of the
        # (cap, d_ff) intermediate over the model axis.
        from repro.launch import sharding as _shd
        h = _shd.act_constraint(h, "model", "data", None)
    # expert_apply dispatches raw banks and expert-axis TT payloads alike
    g = common.activate(common.expert_apply(h, p.w_gate), cfg.act)
    u = common.expert_apply(h, p.w_up)
    if getattr(cfg, "opt_moe_ep", False):
        from repro.launch import sharding as _shd
        g = _shd.act_constraint(g, "model", "data", None)
        u = _shd.act_constraint(u, "model", "data", None)
    out = common.expert_apply(g * u, p.w_down)           # (E, cap, D)
    if getattr(cfg, "opt_moe_ep", False):
        from repro.launch import sharding as _shd
        out = _shd.act_constraint(out, "model", "data", None)
    out = out.reshape(e * cap, d)

    # gather back + weighted combine
    per_slot = out[slot]                                 # (n*k, d)
    w = (topk_p.reshape(-1) * keep).astype(jnp.float32)[:, None]
    combined = (per_slot.astype(jnp.float32) * w).reshape(n, k, d).sum(1)
    return combined.reshape(b, s, d).astype(x.dtype)


def router_aux_stats(x, p: MoEParams, cfg):
    """(load-balance loss, router z-loss) for the training objective."""
    n = x.shape[0] * x.shape[1]
    logits = common.dense_apply(x.astype(jnp.float32),
                                p.router.astype(jnp.float32)).reshape(n, -1)
    probs = jax.nn.softmax(logits, axis=-1)
    _, topk_e = jax.lax.top_k(probs, cfg.moe.num_experts_per_tok)
    e = cfg.moe.num_experts
    counts = jnp.zeros((e,), jnp.float32).at[topk_e.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = probs.mean(0)
    lb = e * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return lb, z
