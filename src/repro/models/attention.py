"""Attention: GQA with qk-norm/bias/window/softcap; XLA-chunked prefill path
(memory-safe at 32k on the dry-run) and log-sum-exp-mergeable decode path
(keeps the KV cache shardable along SEQUENCE on the model axis — the
flash-decode formulation XLA SPMD turns into small partial-softmax
collectives instead of gathering a 500k-token cache).

The Pallas flash kernel (``kernels/flash_attention``) is the TPU execution
target for prefill; ``impl="pallas"`` switches to it.  Dry-run lowering uses
``impl="xla"`` so cost_analysis reflects pure-XLA collectives/FLOPs.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common

NEG_INF = -2.0e38


class AttnParams(NamedTuple):
    """Shapes (per layer; stack a leading L axis for scan).

    wq: (D, Hq, Dh)   wk/wv: (D, Hkv, Dh)   wo: (Hq, Dh, D)
    bq: (Hq, Dh) | None  (QKV bias archs)
    q_norm/k_norm: (Dh,) | None (qk-norm archs)
    """
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None
    q_norm: Optional[jax.Array] = None
    k_norm: Optional[jax.Array] = None


def init_attn(key, cfg, layers: Optional[int] = None) -> AttnParams:
    d, hq, hkv, dh = (
        cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    )
    dt = common.cdtype(cfg)
    ks = jax.random.split(key, 4)

    def mk(shape, k, in_axis=0):
        if layers is None:
            return common.dense_init(k, shape, in_axis, dt)
        return jax.vmap(
            lambda kk: common.dense_init(kk, shape, in_axis, dt)
        )(jax.random.split(k, layers))

    zeros = lambda shape: (
        jnp.zeros(shape, dt) if layers is None
        else jnp.zeros((layers, *shape), dt)
    )
    return AttnParams(
        wq=mk((d, hq, dh), ks[0]),
        wk=mk((d, hkv, dh), ks[1]),
        wv=mk((d, hkv, dh), ks[2]),
        wo=mk((hq, dh, d), ks[3], 0),
        bq=zeros((hq, dh)) if cfg.qkv_bias else None,
        bk=zeros((hkv, dh)) if cfg.qkv_bias else None,
        bv=zeros((hkv, dh)) if cfg.qkv_bias else None,
        q_norm=zeros((dh,)) if cfg.qk_norm else None,
        k_norm=zeros((dh,)) if cfg.qk_norm else None,
    )


def qkv_project(x, p: AttnParams, cfg, positions):
    # dense_apply dispatches raw arrays and TT payloads identically
    q = common.dense_apply(x, p.wq)
    k = common.dense_apply(x, p.wk)
    v = common.dense_apply(x, p.wv)
    if p.bq is not None:
        q = q + p.bq
        k = k + p.bk
        v = v + p.bv
    if p.q_norm is not None:
        q = common.rms_norm(q, p.q_norm, cfg.norm_eps)
        k = common.rms_norm(k, p.k_norm, cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, bf16_out: bool = False):
    """(B,S,Hq,D) x (B,T,Hkv,D) -> (B,Hkv,G,S,T) without repeating KV."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    if bf16_out:
        # §Perf: the dot itself emits bf16 (f32 MXU accumulation) so the
        # S×T logit buffer on HBM is half-width.
        return jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.bfloat16),
                          k.astype(jnp.bfloat16))
    return jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                      k.astype(jnp.float32))


def _gqa_out(p, v, bf16_probs: bool = False):
    """(B,Hkv,G,S,T) x (B,T,Hkv,D) -> (B,S,Hq,D)."""
    b, hkv, g, s, t = p.shape
    if bf16_probs:
        # §Perf hillclimb 2: probabilities are in [0,1] post-softmax — bf16
        # storage halves the dominant S×T traffic; the PV matmul still
        # accumulates in f32 (preferred_element_type).
        out = jnp.einsum(
            "bhgst,bthd->bshgd", p.astype(jnp.bfloat16),
            v.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
        )
    else:
        out = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, hkv * g, -1)


def causal_attend(
    q: jax.Array,                # (B, S, Hq, Dh)
    k: jax.Array,                # (B, S, Hkv, Dh)
    v: jax.Array,
    cfg,
    window: Optional[int] = None,
    is_global=False,             # traced bool: widens the window to infinity
    chunk: int = 1024,
    impl: str = "xla",
) -> jax.Array:
    """Causal (optionally windowed) self-attention, O(S·chunk) memory.

    ``is_global`` may be a traced boolean (gemma3's scanned layer flag): the
    window constraint is OR-ed away branchlessly so one scan body serves
    both local and global layers without doubling attention FLOPs.
    """
    if impl == "pallas" and not isinstance(is_global, jax.core.Tracer):
        from repro.kernels.flash_attention.ops import mha_flash
        win = None if (window is None or is_global) else window
        return mha_flash(q, k, v, causal=True, window=win).astype(q.dtype)

    b, s, hq, dh = q.shape
    scale = dh ** -0.5
    if s <= chunk:
        return _attend_block(
            q, k, v, jnp.arange(s), cfg, window, is_global, scale
        )

    assert s % chunk == 0
    nq = s // chunk

    def attend(qblk, kk, vv, pos, ig, k_off=0):
        return _attend_block(qblk, kk, vv, pos, cfg, window, ig, scale,
                             k_off=k_off)

    if getattr(cfg, "opt_attn_remat", False):
        # flash-style nested remat: each q-chunk's S×chunk score tensor is
        # recomputed in its own backward instead of being stacked across the
        # scan as an O(S²) residual (§Perf hillclimb 1).
        attend = jax.checkpoint(attend, static_argnums=(5,))

    if getattr(cfg, "opt_causal_unroll", False):
        # §Perf hillclimb 4: unroll the q-chunk loop so chunk i attends to a
        # STATIC K/V slice — the all-masked future blocks (and, for windowed
        # non-global layers, the expired past) are never computed.  Causal
        # savings: 1 - (nq+1)/2nq ≈ ½ of the full-K score FLOPs and bytes.
        static_local = (window is not None
                        and not isinstance(is_global, jax.core.Tracer)
                        and not bool(is_global))
        outs_u = []
        prev = None
        for qi in range(nq):
            lo = 0
            if static_local:
                lo = max(0, qi * chunk - window + 1) // chunk * chunk
            hi = (qi + 1) * chunk
            pos = qi * chunk + jnp.arange(chunk)
            qblk = q[:, qi * chunk:hi]
            if prev is not None:
                # chain chunks so the scheduler cannot keep all nq score
                # buffers live at once (the scan this replaces serialized
                # them anyway); at 32k/1024 = 32 chunks this is the
                # difference between 1× and 32× peak score memory.
                qblk, _ = jax.lax.optimization_barrier((qblk, prev))
            out = attend(qblk, k[:, lo:hi], v[:, lo:hi], pos, is_global, lo)
            prev = out
            outs_u.append(out)
        return jnp.concatenate(outs_u, axis=1)

    def body(carry, qi):
        qblk = jax.lax.dynamic_slice_in_dim(q, qi * chunk, chunk, axis=1)
        pos = qi * chunk + jnp.arange(chunk)
        out = attend(qblk, k, v, pos, is_global)
        return carry, out

    _, outs = jax.lax.scan(body, 0, jnp.arange(nq))      # (nq, B, chunk, H, D)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, dh)


def _attend_block(qblk, k, v, q_pos, cfg, window, is_global, scale, k_off=0):
    t = k.shape[1]
    k_pos = k_off + jnp.arange(t)
    mask = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        in_window = q_pos[:, None] - k_pos[None, :] < window
        mask &= in_window | jnp.asarray(is_global)
    bf16_scores = getattr(cfg, "opt_bf16_scores", False)
    scores = _gqa_scores(qblk, k, bf16_out=bf16_scores) * jnp.asarray(
        scale, jnp.bfloat16 if bf16_scores else jnp.float32)
    if bf16_scores:
        # §Perf hillclimb 3: the S×T logit buffer on HBM is bf16; the
        # max/exp/sum softmax reductions upcast to f32 inside their fusions.
        scores = jnp.where(mask[None, None, None], scores,
                           jnp.asarray(NEG_INF, jnp.bfloat16))
        p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    else:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(
        p, v, bf16_probs=getattr(cfg, "opt_bf16_probs", False)
    ).astype(qblk.dtype)


def cross_attend(q, k, v, cfg, mem_len=None) -> jax.Array:
    """Full cross attention for the encoder-decoder arch.

    ``mem_len`` — optional () or (B,) count of valid memory rows per batch
    row; rows at or past it are masked out (the continuous-batching slot
    contract: a slot's encoder memory occupies a prefix of the fixed-size
    ``mem_k``/``mem_v`` rows, and padding rows must never attract weight).
    ``mem_len == 0`` degrades gracefully: the finite NEG_INF mask leaves a
    uniform softmax over all-zero V rows, i.e. exactly the zero output a
    token-only slot decoded against before masking existed.  ``None`` keeps
    the legacy fully-unmasked behaviour bit-for-bit (no ``where`` traced).
    """
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q, k) * scale
    if mem_len is not None:
        t = k.shape[1]
        valid = jnp.arange(t)[None, :] < jnp.reshape(mem_len, (-1, 1))
        scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(
        p, v, bf16_probs=getattr(cfg, "opt_bf16_probs", False)
    ).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single new token against a long cache)
# ---------------------------------------------------------------------------

def decode_attend(
    q: jax.Array,                # (B, 1, Hq, Dh)
    k_cache: jax.Array,          # (B, S_max, Hkv, Dh)
    v_cache: jax.Array,
    pos: jax.Array,              # () shared or (B,) per-slot position
    cfg,
    window: Optional[int] = None,
    is_global=False,
) -> jax.Array:
    """LSE-mergeable single-token attention over the full cache.

    Written as (max, sum-exp, weighted-V) reductions over the cache's
    sequence axis so GSPMD can keep the cache sequence-sharded on the model
    axis and merge with tiny collectives (flash-decoding semantics).

    ``pos`` may be per-slot (B,) — the continuous-batching contract where
    every batch row sits at its own sequence position — or a shared scalar;
    the validity mask broadcasts over whichever it gets.
    """
    b, _, hq, dh = q.shape
    t = k_cache.shape[1]
    scale = dh ** -0.5
    k_pos = jnp.arange(t)
    posc = jnp.reshape(pos, (-1, 1))                     # (B,1) or (1,1)
    valid = k_pos[None, :] <= posc                       # (B|1, T) incl. self
    if window is not None:
        valid &= (k_pos[None, :] > posc - window) | jnp.asarray(is_global)
    scores = _gqa_scores(q, k_cache) * scale             # (B,Hkv,G,1,T)
    scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    num = _gqa_out(e, v_cache)                           # (B,1,Hq,Dh) fp32
    den = e.sum(axis=-1)                                 # (B,Hkv,G,1)
    den = den.reshape(b, 1, hq, 1)
    return (num / jnp.maximum(den, 1e-30)).astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Insert the new token's K/V at ``pos`` (dynamic index).

    Scalar ``pos`` writes one shared position; per-slot ``pos`` (B,) writes
    each batch row at its own position (vmapped dynamic-update — the slot
    contract the continuous-batching engine steps under).  Both clamp at the
    cache edge, so a frozen finished slot re-writes its last row instead of
    overflowing."""
    if jnp.ndim(pos) == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1
        )
        return k_cache, v_cache
    upd = jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0)
    )
    return (
        upd(k_cache, k_new.astype(k_cache.dtype), pos),
        upd(v_cache, v_new.astype(v_cache.dtype), pos),
    )
