"""Mamba-2 (SSD — state-space duality) language model.

Chunked SSD algorithm (Dao & Gu 2024): intra-chunk quadratic "attention-like"
term + inter-chunk linear state recurrence, both MXU-friendly einsums; the
inter-chunk scan carries an (H, P, N) state — this is what makes long_500k
decode O(1) in sequence length.

Block structure (simplified n_groups=1 Mamba-2):
  in_proj → [z (gate) | x | B | C | dt] → causal depthwise conv on (x,B,C)
  → SiLU → SSD → RMSNorm(gated) → out_proj
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common


class MambaLayerParams(NamedTuple):
    w_in: jax.Array               # (D, 2*Di + 2*N + H)
    conv_w: jax.Array             # (W, Di + 2*N)  depthwise
    conv_b: jax.Array             # (Di + 2*N,)
    a_log: jax.Array              # (H,)
    d_skip: jax.Array             # (H,)
    dt_bias: jax.Array            # (H,)
    gate_norm: jax.Array          # (Di,)
    w_out: jax.Array              # (Di, D)
    ln: jax.Array                 # (D,)


class MambaParams(NamedTuple):
    embed: jax.Array
    layers: MambaLayerParams
    final_norm: jax.Array


def _dims(cfg):
    di = cfg.ssm.expand * cfg.d_model
    h = di // cfg.ssm.head_dim
    return di, h, cfg.ssm.state_dim, cfg.ssm.conv_width


def init(key, cfg) -> MambaParams:
    d = cfg.d_model
    di, h, n, w = _dims(cfg)
    l = cfg.num_layers
    dt = common.cdtype(cfg)
    ks = jax.random.split(key, 6)

    def per_layer(k, shape, in_axis=0):
        return jax.vmap(
            lambda kk: common.dense_init(kk, shape, in_axis, dt)
        )(jax.random.split(k, l))

    # dt bias ~ log-uniform dt init (mamba convention)
    dt0 = np.exp(
        np.random.RandomState(0).uniform(np.log(1e-3), np.log(1e-1), (l, h))
    ).astype(np.float32)
    dt_bias = np.log(np.expm1(dt0))
    a0 = np.random.RandomState(1).uniform(1.0, 16.0, (l, h)).astype(np.float32)

    layers = MambaLayerParams(
        w_in=per_layer(ks[0], (d, 2 * di + 2 * n + h)),
        conv_w=(
            jax.random.normal(ks[1], (l, w, di + 2 * n), jnp.float32) * 0.1
        ).astype(dt),
        conv_b=jnp.zeros((l, di + 2 * n), dt),
        a_log=jnp.asarray(np.log(a0)),
        d_skip=jnp.ones((l, h), jnp.float32),
        dt_bias=jnp.asarray(dt_bias),
        gate_norm=jnp.zeros((l, di), dt),
        w_out=per_layer(ks[2], (di, d)),
        ln=jnp.zeros((l, d), dt),
    )
    return MambaParams(
        embed=common.embed_init(ks[3], (cfg.padded_vocab_size, d), dt),
        layers=layers,
        final_norm=jnp.zeros((d,), dt),
    )


def _split_proj(xz, cfg):
    di, h, n, _ = _dims(cfg)
    z, x, b, c, dt = jnp.split(
        xz, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    return z, x, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along axis 1.  x: (B, S, C), w: (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        xp[:, i: i + x.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    return out + b[None, None, :]


def ssd_chunked(
    x: jax.Array,                 # (B, S, H, P)
    dt: jax.Array,                # (B, S, H)  (softplus'd, positive)
    a: jax.Array,                 # (H,) negative decay rates
    b_mat: jax.Array,             # (B, S, N)
    c_mat: jax.Array,             # (B, S, N)
    chunk: int,
) -> jax.Array:
    """Chunked SSD: returns y (B, S, H, P) for h_t = exp(a·dt_t) h_{t-1} +
    dt_t · b_t x_tᵀ ;  y_t = c_t · h_t."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    if s % chunk != 0:
        # pad the tail; causality keeps earlier outputs exact, padded rows
        # are sliced away before returning
        pad = chunk - s % chunk
        y = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            a,
            jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0))),
            chunk,
        )
        return y[:, :s]
    nc = s // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    # reshape into chunks
    xc = xf.reshape(bsz, nc, chunk, h, p)
    dtc = dtf.reshape(bsz, nc, chunk, h)
    bc = bf.reshape(bsz, nc, chunk, n)
    cc = cf.reshape(bsz, nc, chunk, n)

    la = dtc * a[None, None, None, :]                    # log-decay per step
    cum = jnp.cumsum(la, axis=2)                         # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within chunk; MXU einsums) ----
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    l_mat = jnp.where(causal, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # (B,nc,Q,Q)
    mix_ij = cb[..., None] * l_mat * dtc[:, :, None, :, :]  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", mix_ij, xc)

    # ---- chunk states: S_c = Σ_j exp(cum_Q - cum_j) dt_j b_j x_jᵀ ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,Q,H)
    sb = bc[:, :, :, None, :] * (decay_to_end * dtc)[..., None]  # (B,nc,Q,H,N)
    s_chunk = jnp.einsum("bcqhn,bcqhp->bchnp", sb, xc)   # (B,nc,H,N,P)

    # ---- inter-chunk recurrence over chunk states ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def scan_fn(carry, inp):
        s_c, g = inp                                     # (B,H,N,P), (B,H)
        new = carry * g[..., None, None] + s_c
        return new, carry                                # emit state BEFORE

    init_state = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn,
        init_state,
        (
            jnp.moveaxis(s_chunk, 1, 0),                 # (nc,B,H,N,P)
            jnp.moveaxis(chunk_decay, 1, 0),             # (nc,B,H)
        ),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B,nc,H,N,P)

    # ---- inter-chunk output: y_inter_i = exp(cum_i) c_i · R_{c-1} ----
    c_decay = jnp.exp(cum)                               # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bcqn,bchnp->bcqhp", cc, prev_states
    ) * c_decay[..., None]

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y


def _mamba_mixer(x, lp: MambaLayerParams, cfg):
    di, h, n, _ = _dims(cfg)
    p = cfg.ssm.head_dim
    xz = common.dense_apply(x, lp.w_in)
    z, xi, b, c, dt = _split_proj(xz, cfg)
    conv_in = jnp.concatenate([xi, b, c], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, lp.conv_w, lp.conv_b).astype(jnp.float32)
    )
    xi, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    dtp = jax.nn.softplus(
        dt.astype(jnp.float32) + lp.dt_bias[None, None, :]
    )
    a = -jnp.exp(lp.a_log)
    xh = xi.reshape(*xi.shape[:2], h, p)
    y = ssd_chunked(xh, dtp, a, b, c, cfg.ssm.chunk)
    y = y + lp.d_skip[None, None, :, None] * xh
    y = y.reshape(*x.shape[:2], di)
    y = common.rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
        lp.gate_norm, cfg.norm_eps,
    )
    return common.dense_apply(y, lp.w_out)


def forward(params: MambaParams, tokens, cfg, impl: str = "xla"):
    x = params.embed[tokens].astype(common.cdtype(cfg))

    def body(hcarry, lp):
        def blk(hh, lp):
            hh = common.pin_batch(hh, cfg)
            h2 = common.rms_norm(hh, lp.ln, cfg.norm_eps)
            return (hh + _mamba_mixer(h2, lp, cfg)).astype(hh.dtype)
        fn = jax.checkpoint(blk) if cfg.remat else blk
        return fn(hcarry, lp), None

    x, _ = common.tt_scan(body, x, params.layers, length=cfg.num_layers)
    return common.rms_norm(x, params.final_norm, cfg.norm_eps)


def loss_fn(params, batch, cfg, impl: str = "xla"):
    hidden = forward(params, batch["tokens"], cfg, impl=impl)
    logits = common.unembed(hidden, params.embed, cfg.logit_softcap, real_vocab=cfg.vocab_size)
    loss = common.cross_entropy_loss(
        logits, batch["labels"], batch.get("mask")
    )
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Decode: O(1) state per layer
# ---------------------------------------------------------------------------

class MambaCache(NamedTuple):
    ssm_state: jax.Array          # (L, B, H, N, P) fp32
    conv_state: jax.Array         # (L, B, W-1, Di + 2N)
    pos: jax.Array                # (B,) int32 per-slot step counter


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    di, h, n, w = _dims(cfg)
    p = cfg.ssm.head_dim
    l = cfg.num_layers
    return MambaCache(
        ssm_state=jnp.zeros((l, batch, h, n, p), jnp.float32),
        conv_state=jnp.zeros((l, batch, w - 1, di + 2 * n), dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def decode_step(params: MambaParams, cache: MambaCache, tokens, cfg):
    di, h, n, w = _dims(cfg)
    p = cfg.ssm.head_dim
    x = params.embed[tokens].astype(common.cdtype(cfg))   # (B, 1, D)

    def body(hcarry, lp, s_state, c_state):
        hh = common.rms_norm(hcarry, lp.ln, cfg.norm_eps)
        xz = common.dense_apply(hh, lp.w_in)
        z, xi, b, c, dt = _split_proj(xz, cfg)
        conv_in = jnp.concatenate([xi, b, c], axis=-1)    # (B, 1, C)
        hist = jnp.concatenate([c_state, conv_in], axis=1)  # (B, W, C)
        # lint: skip[AST001] depthwise conv (elementwise over channels),
        # not a weight matmul — dense_apply can't express the "wc,wc" tap
        conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                          lp.conv_w.astype(jnp.float32)) + lp.conv_b
        conv = jax.nn.silu(conv)                          # (B, C)
        xi1, b1, c1 = jnp.split(conv, [di, di + n], axis=-1)
        dtp = jax.nn.softplus(
            dt[:, 0].astype(jnp.float32) + lp.dt_bias[None, :]
        )                                                 # (B, H)
        a = -jnp.exp(lp.a_log)                            # (H,)
        g = jnp.exp(dtp * a[None, :])                     # (B, H)
        xh = xi1.reshape(-1, h, p).astype(jnp.float32)
        # state update: s ← g s + dt · b x^T
        outer = jnp.einsum("bn,bhp->bhnp", b1, xh) * dtp[..., None, None]
        s_new = s_state * g[..., None, None] + outer
        y = jnp.einsum("bn,bhnp->bhp", c1, s_new)
        y = y + lp.d_skip[None, :, None] * xh
        y = y.reshape(-1, 1, di)
        y = common.rms_norm(
            (y * jax.nn.silu(z.astype(jnp.float32))).astype(hcarry.dtype),
            lp.gate_norm, cfg.norm_eps,
        )
        out = hcarry + common.dense_apply(y, lp.w_out)
        return out.astype(hcarry.dtype), (s_new, hist[:, 1:, :])

    x, (s_all, c_all) = common.tt_scan(
        body, x, params.layers, xs=(cache.ssm_state, cache.conv_state),
        length=cfg.num_layers,
    )
    hidden = common.rms_norm(x, params.final_norm, cfg.norm_eps)
    logits = common.unembed(hidden, params.embed, cfg.logit_softcap, real_vocab=cfg.vocab_size)
    return logits[:, 0, :], MambaCache(
        ssm_state=s_all, conv_state=c_all.astype(cache.conv_state.dtype),
        pos=cache.pos + 1,
    )


def prefill(params, tokens, cfg, impl: str = "xla"):
    hidden = forward(params, tokens, cfg, impl=impl)
    logits = common.unembed(hidden[:, -1:, :], params.embed, cfg.logit_softcap, real_vocab=cfg.vocab_size)
    return logits[:, 0, :]


# TT-native serving rules: the mamba2 block's two big matmuls.  The fused
# in-projection (D, 2Di+2N+H) and out-projection (Di, D) dominate the
# layer's weight bytes; conv/gate/decay params are tiny and stay raw.
common.register_tt_serve_rules("ssm", [
    common.TTServeRule(r"^layers\.w_in$", in_ndim=1),
    common.TTServeRule(r"^layers\.w_out$", in_ndim=1),
])
