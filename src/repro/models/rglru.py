"""RecurrentGemma (Griffin): RG-LRU recurrent blocks + local MQA attention,
pattern (rglru, rglru, attn).

The 26-layer stack is scanned as 8 × the 3-layer pattern plus 2 trailing
rglru layers (DESIGN.md §6) — keeping HLO size depth-independent while
honoring the 1-attention : 2-recurrent ratio.

RG-LRU recurrence (per channel, fp32):
    r_t = σ(W_rg x_t + b_rg)           recurrence gate
    i_t = σ(W_ig x_t + b_ig)           input gate
    log a_t = -c · softplus(Λ) · r_t   (c = 8)
    h_t = a_t · h_{t-1} + √(1 − a_t²) · (i_t · x_t)
computed with an associative scan over the sequence — and as a single
multiply-add per step at decode time (the O(1)-state property that makes
long_500k applicable to this arch).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import common
from repro.models import mlp as mlp_mod

RG_C = 8.0


class RGLRULayerParams(NamedTuple):
    ln1: jax.Array                # (D,)
    w_x: jax.Array                # (D, R) main branch
    w_gate: jax.Array             # (D, R) multiplicative branch
    conv_w: jax.Array             # (W, R)
    conv_b: jax.Array             # (R,)
    lam: jax.Array                # (R,) Λ
    w_rg: jax.Array               # (R, R)
    b_rg: jax.Array               # (R,)
    w_ig: jax.Array               # (R, R)
    b_ig: jax.Array               # (R,)
    w_out: jax.Array              # (R, D)
    ln2: jax.Array                # (D,)
    mlp: mlp_mod.MLPParams


class AttnLayerParams(NamedTuple):
    ln1: jax.Array
    attn: attn.AttnParams
    ln2: jax.Array
    mlp: mlp_mod.MLPParams


class TripleParams(NamedTuple):
    r1: RGLRULayerParams
    r2: RGLRULayerParams
    at: AttnLayerParams


class GriffinParams(NamedTuple):
    embed: jax.Array
    triples: TripleParams         # stacked (n_triples, ...)
    tail: Optional[RGLRULayerParams]  # stacked (n_tail, ...)
    final_norm: jax.Array


CONV_W = 4


def _r(cfg) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def _init_rglru(key, cfg, layers: int) -> RGLRULayerParams:
    d, r = cfg.d_model, _r(cfg)
    dt = common.cdtype(cfg)
    ks = jax.random.split(key, 6)

    def mk(k, shape, in_axis=0):
        return jax.vmap(
            lambda kk: common.dense_init(kk, shape, in_axis, dt)
        )(jax.random.split(k, layers))

    # Λ init so a^c spans ~(0.9, 0.999)
    lam0 = np.random.RandomState(7).uniform(0.3, 1.5, (layers, r))
    return RGLRULayerParams(
        ln1=jnp.zeros((layers, d), dt),
        w_x=mk(ks[0], (d, r)),
        w_gate=mk(ks[1], (d, r)),
        conv_w=(jax.random.normal(ks[2], (layers, CONV_W, r)) * 0.1).astype(dt),
        conv_b=jnp.zeros((layers, r), dt),
        lam=jnp.asarray(lam0, jnp.float32),
        w_rg=mk(ks[3], (r, r)),
        b_rg=jnp.zeros((layers, r), dt),
        w_ig=mk(ks[4], (r, r)),
        b_ig=jnp.zeros((layers, r), dt),
        w_out=mk(ks[5], (r, d)),
        ln2=jnp.zeros((layers, d), dt),
        mlp=mlp_mod.init_mlp(ks[5], cfg, layers=layers),
    )


def _init_attn_layer(key, cfg, layers: int) -> AttnLayerParams:
    dt = common.cdtype(cfg)
    ks = jax.random.split(key, 2)
    return AttnLayerParams(
        ln1=jnp.zeros((layers, cfg.d_model), dt),
        attn=attn.init_attn(ks[0], cfg, layers=layers),
        ln2=jnp.zeros((layers, cfg.d_model), dt),
        mlp=mlp_mod.init_mlp(ks[1], cfg, layers=layers),
    )


def plan(cfg) -> Tuple[int, int]:
    """(n_triples, n_tail_rglru) for the layer budget."""
    n_triples = cfg.num_layers // 3
    n_tail = cfg.num_layers - 3 * n_triples
    return n_triples, n_tail


def init(key, cfg) -> GriffinParams:
    n_triples, n_tail = plan(cfg)
    ks = jax.random.split(key, 5)
    triples = TripleParams(
        r1=_init_rglru(ks[0], cfg, n_triples),
        r2=_init_rglru(ks[1], cfg, n_triples),
        at=_init_attn_layer(ks[2], cfg, n_triples),
    )
    tail = _init_rglru(ks[3], cfg, n_tail) if n_tail else None
    return GriffinParams(
        embed=common.embed_init(
            ks[4], (cfg.padded_vocab_size, cfg.d_model), common.cdtype(cfg)
        ),
        triples=triples,
        tail=tail,
        final_norm=jnp.zeros((cfg.d_model,), common.cdtype(cfg)),
    )


def rg_lru_scan(x: jax.Array, gates_r, gates_i, lam) -> jax.Array:
    """x, gates: (B, S, R) fp32.  Associative linear recurrence."""
    log_a = -RG_C * jax.nn.softplus(lam)[None, None, :] * gates_r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (gates_i * x)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _rglru_block(x, lp: RGLRULayerParams, cfg):
    h = common.rms_norm(x, lp.ln1, cfg.norm_eps)
    main = common.dense_apply(h, lp.w_x)
    gate = jax.nn.gelu(
        common.dense_apply(h, lp.w_gate).astype(jnp.float32)
    )
    conv = _conv1d(main, lp.conv_w, lp.conv_b).astype(jnp.float32)
    # fp32 activations: dense_apply upcasts the raw gate weights to match
    # (the explicit .astype(f32) einsums this replaces)
    gr = jax.nn.sigmoid(
        common.dense_apply(conv, lp.w_rg) + lp.b_rg.astype(jnp.float32)
    )
    gi = jax.nn.sigmoid(
        common.dense_apply(conv, lp.w_ig) + lp.b_ig.astype(jnp.float32)
    )
    hseq = rg_lru_scan(conv, gr, gi, lp.lam)
    y = (hseq * gate).astype(x.dtype)
    x = x + common.dense_apply(y, lp.w_out)
    h = common.rms_norm(x, lp.ln2, cfg.norm_eps)
    return (x + mlp_mod.mlp_apply(h, lp.mlp, cfg.act)).astype(x.dtype)


def _conv1d(x, w, b):
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    return sum(
        xp[:, i: i + x.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    ) + b[None, None, :]


def _attn_block(x, lp: AttnLayerParams, cfg, positions, impl):
    h = common.rms_norm(x, lp.ln1, cfg.norm_eps)
    q, k, v = attn.qkv_project(h, lp.attn, cfg, positions)
    o = attn.causal_attend(
        q, k, v, cfg, window=cfg.hybrid.window, impl=impl
    )
    x = x + common.dense_apply(o, lp.attn.wo, in_ndim=2)
    h = common.rms_norm(x, lp.ln2, cfg.norm_eps)
    return (x + mlp_mod.mlp_apply(h, lp.mlp, cfg.act)).astype(x.dtype)


def forward(params: GriffinParams, tokens, cfg, impl: str = "xla"):
    x = params.embed[tokens].astype(common.cdtype(cfg))
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    n_triples, n_tail = plan(cfg)

    def triple(h, tp: TripleParams):
        def blk(hh, tp):
            hh = common.pin_batch(hh, cfg)
            hh = _rglru_block(hh, tp.r1, cfg)
            hh = _rglru_block(hh, tp.r2, cfg)
            return _attn_block(hh, tp.at, cfg, positions, impl)
        fn = jax.checkpoint(blk) if cfg.remat else blk
        return fn(h, tp), None

    x, _ = common.tt_scan(triple, x, params.triples, length=n_triples)
    if params.tail is not None:
        def tail_blk(h, lp):
            fn = jax.checkpoint(
                lambda hh, lp: _rglru_block(hh, lp, cfg)
            ) if cfg.remat else (lambda hh, lp: _rglru_block(hh, lp, cfg))
            return fn(h, lp), None
        x, _ = common.tt_scan(tail_blk, x, params.tail, length=n_tail)
    return common.rms_norm(x, params.final_norm, cfg.norm_eps)


def loss_fn(params, batch, cfg, impl: str = "xla"):
    hidden = forward(params, batch["tokens"], cfg, impl=impl)
    logits = common.unembed(hidden, params.embed, cfg.logit_softcap, real_vocab=cfg.vocab_size)
    loss = common.cross_entropy_loss(
        logits, batch["labels"], batch.get("mask")
    )
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Decode: O(1) recurrent state + ring-buffer window cache
# ---------------------------------------------------------------------------

class GriffinCache(NamedTuple):
    # recurrent state per rglru layer
    h1: jax.Array                 # (n_triples, B, R) fp32
    h2: jax.Array
    ht: jax.Array                 # (n_tail, B, R)
    conv1: jax.Array              # (n_triples, B, W-1, R)
    conv2: jax.Array
    convt: jax.Array
    # ring KV cache for attention layers (window-sized!)
    k: jax.Array                  # (n_triples, B, window, Hkv, Dh)
    v: jax.Array
    pos: jax.Array                # (B,) int32 per-slot (scalar also accepted)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    nt, ntail = plan(cfg)
    r = _r(cfg)
    win = min(cfg.hybrid.window, max_len)
    kvshape = (nt, batch, win, cfg.num_kv_heads, cfg.resolved_head_dim)
    return GriffinCache(
        h1=jnp.zeros((nt, batch, r), jnp.float32),
        h2=jnp.zeros((nt, batch, r), jnp.float32),
        ht=jnp.zeros((max(ntail, 1), batch, r), jnp.float32),
        conv1=jnp.zeros((nt, batch, CONV_W - 1, r), dtype),
        conv2=jnp.zeros((nt, batch, CONV_W - 1, r), dtype),
        convt=jnp.zeros((max(ntail, 1), batch, CONV_W - 1, r), dtype),
        k=jnp.zeros(kvshape, dtype),
        v=jnp.zeros(kvshape, dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def _rglru_step(x, lp: RGLRULayerParams, cfg, h_state, conv_state):
    """x: (B, 1, D).  Returns (out, h_state', conv_state')."""
    h = common.rms_norm(x, lp.ln1, cfg.norm_eps)
    main = common.dense_apply(h, lp.w_x)[:, 0]             # (B, R)
    gate = jax.nn.gelu(
        common.dense_apply(h, lp.w_gate)[:, 0].astype(jnp.float32)
    )
    hist = jnp.concatenate(
        [conv_state, main[:, None, :].astype(conv_state.dtype)], axis=1
    )                                                      # (B, W, R)
    # lint: skip[AST001] depthwise conv (elementwise over channels), not a
    # weight matmul — dense_apply can't express the "wr,wr" tap
    conv = jnp.einsum(
        "bwr,wr->br", hist.astype(jnp.float32), lp.conv_w.astype(jnp.float32)
    ) + lp.conv_b.astype(jnp.float32)
    gr = jax.nn.sigmoid(common.dense_apply(conv, lp.w_rg)
                        + lp.b_rg.astype(jnp.float32))
    gi = jax.nn.sigmoid(common.dense_apply(conv, lp.w_ig)
                        + lp.b_ig.astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(lp.lam)[None, :] * gr
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h_new = a * h_state + beta * (gi * conv)
    y = (h_new * gate).astype(x.dtype)[:, None, :]
    x = x + common.dense_apply(y, lp.w_out)
    hn = common.rms_norm(x, lp.ln2, cfg.norm_eps)
    out = (x + mlp_mod.mlp_apply(hn, lp.mlp, cfg.act)).astype(x.dtype)
    return out, h_new, hist[:, 1:, :]


def _attn_step(x, lp: AttnLayerParams, cfg, k_c, v_c, pos):
    """Ring-buffer windowed MQA decode step.

    ``pos`` may be a shared scalar or per-slot (B,): each batch row keeps
    its own ring write slot and validity horizon (continuous batching)."""
    win = k_c.shape[1]
    h = common.rms_norm(x, lp.ln1, cfg.norm_eps)
    positions = jnp.broadcast_to(
        jnp.reshape(pos, (-1, 1)), (x.shape[0], 1)
    )
    q, k_new, v_new = attn.qkv_project(h, lp.attn, cfg, positions)
    slot = jnp.mod(pos, win)
    if jnp.ndim(pos) == 0:
        k_c = jax.lax.dynamic_update_slice_in_dim(
            k_c, k_new.astype(k_c.dtype), slot, axis=1
        )
        v_c = jax.lax.dynamic_update_slice_in_dim(
            v_c, v_new.astype(v_c.dtype), slot, axis=1
        )
    else:
        upd = jax.vmap(
            lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(
                c, n, s, axis=0
            )
        )
        k_c = upd(k_c, k_new.astype(k_c.dtype), slot)
        v_c = upd(v_c, v_new.astype(v_c.dtype), slot)
    # ring validity: slots hold positions (pos-win, pos]; all valid once full
    slots = jnp.arange(win)
    slot2 = jnp.reshape(slot, (-1, 1))                     # (B|1, 1)
    age = jnp.mod(slot2 - slots[None, :], win)             # 0 = newest
    valid = age <= jnp.minimum(jnp.reshape(pos, (-1, 1)), win - 1)
    scores = attn._gqa_scores(q, k_c) * (q.shape[-1] ** -0.5)
    scores = jnp.where(valid[:, None, None, None, :], scores, attn.NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = attn._gqa_out(p, v_c).astype(x.dtype)
    x = x + common.dense_apply(o, lp.attn.wo, in_ndim=2)
    hn = common.rms_norm(x, lp.ln2, cfg.norm_eps)
    out = (x + mlp_mod.mlp_apply(hn, lp.mlp, cfg.act)).astype(x.dtype)
    return out, k_c, v_c


def decode_step(params: GriffinParams, cache: GriffinCache, tokens, cfg):
    x = params.embed[tokens].astype(common.cdtype(cfg))
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pos = cache.pos
    n_triples, n_tail = plan(cfg)

    def triple(h, tp, h1, h2, c1, c2, k_c, v_c):
        h, h1n, c1n = _rglru_step(h, tp.r1, cfg, h1, c1)
        h, h2n, c2n = _rglru_step(h, tp.r2, cfg, h2, c2)
        h, k_cn, v_cn = _attn_step(h, tp.at, cfg, k_c, v_c, pos)
        return h, (h1n, h2n, c1n, c2n, k_cn, v_cn)

    x, (h1, h2, c1, c2, k_all, v_all) = common.tt_scan(
        triple, x, params.triples,
        xs=(cache.h1, cache.h2, cache.conv1, cache.conv2,
            cache.k, cache.v),
        length=n_triples,
    )
    ht, ct = cache.ht, cache.convt
    if params.tail is not None:
        def tail_fn(h, lp, hs, cs):
            h, hn, cn = _rglru_step(h, lp, cfg, hs, cs)
            return h, (hn, cn)
        x, (ht, ct) = common.tt_scan(
            tail_fn, x, params.tail, xs=(cache.ht, cache.convt),
            length=n_tail,
        )
    hidden = common.rms_norm(x, params.final_norm, cfg.norm_eps)
    logits = common.unembed(hidden, params.embed, cfg.logit_softcap, real_vocab=cfg.vocab_size)
    return logits[:, 0, :], GriffinCache(
        h1=h1, h2=h2, ht=ht,
        conv1=c1.astype(cache.conv1.dtype),
        conv2=c2.astype(cache.conv2.dtype),
        convt=ct.astype(cache.convt.dtype),
        k=k_all, v=v_all, pos=pos + 1,
    )


def prefill(params, tokens, cfg, impl: str = "xla"):
    hidden = forward(params, tokens, cfg, impl=impl)
    logits = common.unembed(hidden[:, -1:, :], params.embed, cfg.logit_softcap, real_vocab=cfg.vocab_size)
    return logits[:, 0, :]


# TT-native serving rules: the RG-LRU projections (main/gate/recurrence/
# input-gate/out) and the attention+MLP weights of both the scanned triples
# and the tail layers.  Conv and Λ params are tiny and stay raw.
_RGLRU_W = r"(w_x|w_gate|w_rg|w_ig|w_out)"
common.register_tt_serve_rules("hybrid", [
    common.TTServeRule(rf"^triples\.(r1|r2)\.{_RGLRU_W}$", in_ndim=1),
    common.TTServeRule(r"^triples\.(r1|r2)\.mlp\.w_(gate|up|down)$",
                       in_ndim=1),
    common.TTServeRule(r"^triples\.at\.attn\.w[qkv]$", in_ndim=1),
    common.TTServeRule(r"^triples\.at\.attn\.wo$", in_ndim=2),
    common.TTServeRule(r"^triples\.at\.mlp\.w_(gate|up|down)$", in_ndim=1),
    common.TTServeRule(rf"^tail\.{_RGLRU_W}$", in_ndim=1),
    common.TTServeRule(r"^tail\.mlp\.w_(gate|up|down)$", in_ndim=1),
])
