"""Shared model machinery: building blocks, TT-serving registry, decode driver.

Three layers live here (everything family-agnostic; per-family code stays
in its own module):

  * **building blocks** — norms, RoPE, embeddings, initializers, and
    ``dense_apply``/``expert_apply``, the single raw-vs-TT weight dispatch
    points every matmul in the zoo routes through;
  * **TT-native serving plumbing** — the per-family rule registry
    (``register_tt_serve_rules``/``tt_native_params``) and the TT-aware
    layer scan (``tt_scan``/``layer_at``) that keep TT cores closure
    constants of every scanned forward/decode body;
  * **the fused decode driver** — ``GenState``/``gen_init``/``gen_step``/
    ``gen_scan``: the whole generation loop (prompt consumption, sampling,
    append, step) as one ``lax.scan`` computation, including per-slot
    sampling params, the device-resident admission queue (``ScanQueue``)
    and the retired-slot output buffer (``DoneBuf``) the continuous-
    batching engine schedules against.

Conventions (used by every arch in the zoo):
  * parameters are nested dicts; per-layer tensors are STACKED on a leading
    (num_layers,) axis so layers run under ``jax.lax.scan`` — this keeps HLO
    size and compile time independent of depth (essential for the 512-way
    dry-run compiles).
  * compute dtype is bf16; norms, softmax, and losses run fp32.
  * initializers take an explicit key and are only materialized for reduced
    (smoke-test) configs and the ~100M example — full-size configs are
    touched exclusively through ``jax.eval_shape``.
"""

from __future__ import annotations

import re
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def pin_batch(x, cfg):
    """opt_batch_pin: re-assert batch-dim data sharding inside scan bodies
    (GSPMD can drop it across scan/jvp boundaries, silently replicating the
    batch; see EXPERIMENTS.md §Perf seamless)."""
    if getattr(cfg, "opt_batch_pin", False):
        from repro.launch import sharding as _shd
        return _shd.act_constraint(x, "data", *([None] * (x.ndim - 1)))
    return x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,                # (..., S, H, D)
    positions: jax.Array,        # (..., S)
    theta: float,
) -> jax.Array:
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activate(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {act}")


def dense_apply(x: jax.Array, w, in_ndim: int = 1) -> jax.Array:
    """THE dense-weight application point: every matmul against a model
    weight in the zoo routes through here, so a weight can be either a raw
    array or a TT payload (``core/tt_linear.TTLinear``) without the call
    sites knowing.

    Raw ``w``: shape (*in_dims, *out_dims) with ``in_ndim`` leading input
    axes; contracts x's trailing ``in_ndim`` axes against them (identical
    lowering to the einsums this replaces — one dot_general; mismatched
    dtypes PROMOTE like the einsums did, so an fp32 activation against a
    bf16 gate weight computes in fp32 and a high-precision weight is never
    silently downcast).  TTLinear ``w``: contracts the activation straight
    through the TT cores via the fused ``kernels/tt_contract`` chain — the
    full dense matrix is never materialized.  Quantized TTLinear leaves
    (int8 cores + scales) take the same branch: ``tt_apply`` hands the
    storage-dtype cores and their scales to the dequant-fused kernels, so
    every family serves from int8 with zero model-code changes.
    """
    from repro.core import tt_linear as _ttl
    if _ttl.is_tt_linear(w):
        return _ttl.tt_apply(x, w)
    if w.dtype != x.dtype:
        dt = jnp.promote_types(x.dtype, w.dtype)
        x = x.astype(dt)
        w = w.astype(dt)
    cdims = (
        tuple(range(x.ndim - in_ndim, x.ndim)),
        tuple(range(in_ndim)),
    )
    return jax.lax.dot_general(x, w, (cdims, ((), ())))


def expert_apply(x: jax.Array, w) -> jax.Array:
    """Expert-banked weight application: x (E, C, IN) against w (E, IN, OUT)
    — the MoE FFN's batched matmul.  Raw banks lower to the einsum they
    replace; an expert-axis TTLinear contracts the whole bank straight from
    cores via the expert-batched TT chain (``tt_apply_experts``) —
    quantized banks included (per-(layer, expert)-row lead scales, shared
    int8 tail cores dequantized inside the batched kernel)."""
    from repro.core import tt_linear as _ttl
    if _ttl.is_tt_linear(w):
        return _ttl.tt_apply_experts(x, w)
    return jnp.einsum("eci,eio->eco", x, w)


# ---------------------------------------------------------------------------
# TT-native serving: per-family rule registry + TT-aware layer-scan plumbing
# ---------------------------------------------------------------------------

class TTServeRule(NamedTuple):
    """One eligible-weight pattern of a family's params tree.

    pattern — regex over the dot-joined pytree path of the weight;
    in_ndim — matmul input axes after the stack/expert axes;
    stack   — leading layer-stack axes contracted into the lead table;
    experts — trailing stack axes that form an expert bank (kept as a batch
              axis at apply time; served via the expert-batched chain).
    """
    pattern: "re.Pattern[str]"
    in_ndim: int
    stack: int = 1
    experts: int = 0


# family name -> rules, registered BESIDE each model module (see the
# ``register_tt_serve_rules`` calls at the bottom of transformer.py,
# encdec.py, mamba2.py, rglru.py) — common.py owns only the mechanism.
_TT_SERVE_REGISTRY: dict = {}


def register_tt_serve_rules(family: str, rules) -> None:
    """Register a family's TT-native serving rules (str patterns compiled)."""
    compiled = []
    for r in rules:
        if not isinstance(r, TTServeRule):
            r = TTServeRule(*r)
        if isinstance(r.pattern, str):
            r = r._replace(pattern=re.compile(r.pattern))
        compiled.append(r)
    _TT_SERVE_REGISTRY[family] = tuple(compiled)


def tt_serve_rules(family: Optional[str] = None):
    """Rules for one family, or the union over every registered family
    (path namespaces are disjoint across the zoo, so the union is safe —
    used when the caller doesn't know which family a payload came from)."""
    from repro.models import registry as _registry  # noqa: F401  (lazy:
    # importing the registry imports every model module, which registers
    # its rules as a side effect — common.py itself stays model-agnostic)
    if family is not None:
        return _TT_SERVE_REGISTRY.get(family, ())
    out = []
    for fam in sorted(_TT_SERVE_REGISTRY):
        out.extend(_TT_SERVE_REGISTRY[fam])
    return tuple(out)


def layers_have_tt(layers) -> bool:
    """True when a stacked layer tree carries any TTLinear leaf."""
    from repro.core.tt_linear import is_tt_linear
    return any(
        is_tt_linear(leaf)
        for leaf in jax.tree.leaves(layers, is_leaf=is_tt_linear)
    )


def layer_at(layers, idx):
    """Layer ``idx``'s params from a stacked tree (``idx`` may be traced).

    Raw leaves gather their idx-th row — same dynamic-slice the scan's xs
    mechanism would emit.  TTLinear leaves gather only their (L, r) lead
    vector; the shared cores stay closure constants, so the TT-native scan
    body keeps HLO size depth-independent without duplicating cores per
    layer (the reason TT weights cannot ride in the scan's xs).  Both
    gathers clamp out-of-range indices (``mode="clip"``) — pinned so traced
    and concrete indices behave identically."""
    from repro.core.tt_linear import is_tt_linear, select_layer

    def sel(leaf):
        if is_tt_linear(leaf):
            return select_layer(leaf, idx)
        return jnp.take(leaf, idx, axis=0, mode="clip")

    return jax.tree.map(sel, layers, is_leaf=is_tt_linear)


def tt_scan(fn, init, layers, xs=(), length: Optional[int] = None):
    """``lax.scan`` over a stacked layer tree, TT-aware.

    fn(carry, layer_params, *xs_slices) -> (carry, out).  Dense trees scan
    the params as xs (the stock pattern); trees holding TTLinear leaves
    scan the layer INDEX instead and gather each layer's params inside the
    body (``layer_at``) — cores must stay closure constants, never scan
    xs.  Every family's forward/decode stack runs through here, so TT-
    native serving is a property of the scan plumbing, not of one model.
    """
    if layers_have_tt(layers):
        assert length is not None, "tt_scan over TT leaves needs length"

        def body_tt(carry, scanned):
            return fn(carry, layer_at(layers, scanned[0]), *scanned[1:])

        return jax.lax.scan(body_tt, init, (jnp.arange(length), *xs))

    def body(carry, scanned):
        return fn(carry, scanned[0], *scanned[1:])

    return jax.lax.scan(body, init, (layers, *xs))


# ---------------------------------------------------------------------------
# Fused decode driver: the whole generation loop as ONE lax.scan computation
# ---------------------------------------------------------------------------

class Sampling(NamedTuple):
    """Static sampling configuration for the decode drivers.

    temperature — 0.0 selects greedy argmax (bit-identical to the pre-
                  sampling driver: no PRNG math is even traced); > 0 scales
                  logits by 1/temperature before categorical sampling.
    top_k       — keep only the k highest logits before sampling (ties at
                  the k-th value are all kept); None disables the filter.
    per_slot    — ignore the two static fields and sample each slot under
                  its own ``GenState.temp``/``GenState.topk`` entry (the
                  per-request sampling params the continuous-batching
                  engine writes at admission).  Slots with ``temp == 0``
                  take the greedy argmax — token-identical to the static
                  greedy path.

    The tuple is hashable, so it rides the jitted drivers as a static
    argument — each distinct (temperature, top_k, per_slot) compiles once.
    """
    temperature: float = 0.0
    top_k: Optional[int] = None
    per_slot: bool = False


GREEDY = Sampling()
PER_SLOT = Sampling(per_slot=True)


def make_sampling(temperature: float, top_k: Optional[int]) -> Sampling:
    """Validated Sampling for the serving front doors: a negative
    temperature would silently sample an INVERTED distribution (it passes
    the == 0 greedy check), and top_k <= 0 only surfaces as an opaque
    broadcast error deep inside the jitted scan — reject both up front."""
    temperature = float(temperature)
    if temperature < 0.0:
        raise ValueError(
            f"temperature must be >= 0 (0 = greedy), got {temperature}"
        )
    if top_k is not None:
        top_k = int(top_k)
        if top_k < 1:
            raise ValueError(f"top_k must be >= 1 (or None), got {top_k}")
    return Sampling(temperature, top_k)


def slot_keys(seed: int, b: int) -> jax.Array:
    """Per-row sampling base keys for a ``b``-row generation: row ``r``
    gets ``fold_in(PRNGKey(seed), r)``.  The continuous-batching engine
    gives each request the row-0 key of its own seed, so a request samples
    the same stream whether it runs isolated (batch row 0) or staggered in
    an arbitrary slot."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda r: jax.random.fold_in(base, r))(jnp.arange(b))


def sample_tokens(logits: jax.Array, keys: jax.Array,
                  sampling: Sampling) -> jax.Array:
    """Temperature/top-k sample one token per row (greedy is the caller's
    branch — this function requires temperature > 0).

    logits (B, V) are scaled by 1/temperature in fp32, optionally top-k
    masked, then sampled with ``jax.random.categorical`` under each row's
    own key — the per-row keys are what keep staggered slots independent.
    """
    assert sampling.temperature > 0.0, "greedy path must not sample"
    scaled = logits.astype(jnp.float32) / sampling.temperature
    if sampling.top_k is not None and sampling.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(scaled, sampling.top_k)[0][..., -1:]
        scaled = jnp.where(scaled >= kth, scaled,
                           jnp.asarray(-1e30, jnp.float32))
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)


def sample_tokens_per_slot(logits: jax.Array, keys: jax.Array,
                           temperature: jax.Array,
                           top_k: jax.Array) -> jax.Array:
    """Per-slot temperature/top-k sampling: slot ``i`` samples under
    ``(temperature[i], top_k[i])`` — the per-request params the engine
    writes at admission (``top_k == 0`` disables the filter for that slot).

    Value-identical to the static ``sample_tokens`` path at equal params
    (same scaling, same kth-largest threshold with ties kept, same
    per-row categorical keys), so a request sampled in a mixed-params slot
    pool matches its isolated static-``Sampling`` run token for token.
    Slots with ``temperature == 0`` take the greedy argmax of the raw
    logits — token-identical to the static greedy path (the PRNG math is
    traced but its result discarded by the select).
    """
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temperature > 0.0, temperature, 1.0)
    scaled = lf / safe_t[:, None]
    # per-row kth-largest threshold: a descending sort's (k-1)-th column is
    # exactly lax.top_k(scaled, k)[0][..., -1] — but k may differ per row
    srt = -jnp.sort(-scaled, axis=-1)
    k = jnp.where(top_k > 0, top_k, v)
    kth = jnp.take_along_axis(srt, jnp.clip(k - 1, 0, v - 1)[:, None], axis=1)
    scaled = jnp.where(scaled >= kth, scaled, jnp.asarray(-1e30, jnp.float32))
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


class ScanQueue(NamedTuple):
    """Device-resident admission queue the fused scan admits from.

    A FIFO of pending requests living ON the device, so a retired slot is
    refilled inside the scan body (at most one whole-pool admission sweep
    per step) — a fused chunk never has to end at a boundary just to admit.
    The host refills the buffers between chunks (one donated dispatch) and
    mirrors the admission arithmetic exactly (deterministic lengths, FIFO
    order, lowest-free-slot placement), so scheduling still needs no
    device→host readback.

    tokens (Q, T_max) / prompt_len (Q,) / total_len (Q,) / rng (Q, 2) /
    temp (Q,) / topk (Q,) — one pending request per row, same meaning as
    the GenState per-slot fields they are copied into at admission;
    head () — next row to admit;  size () — valid rows.
    """
    tokens: jax.Array
    prompt_len: jax.Array
    total_len: jax.Array
    rng: jax.Array
    temp: jax.Array
    topk: jax.Array
    head: jax.Array
    size: jax.Array


class DoneBuf(NamedTuple):
    """Retired-slot output rows, appended inside the scan.

    With in-scan admission a slot can retire AND be re-occupied within one
    chunk, overwriting its token row — so the step that retires a slot
    first copies its tokens/prompt_logits here (slot order within a step;
    ``count`` rows are valid).  The host drains the buffer at the chunk
    boundary and resets ``count`` in the refill dispatch.
    """
    tokens: jax.Array          # (D, T_max) int32
    prompt_logits: jax.Array   # (D, V) fp32
    count: jax.Array           # () int32
    bad: Optional[jax.Array] = None   # (D,) bool — retired slot tripped the
                                      # NaN/Inf logit guard (quarantined)


def make_scan_queue(capacity: int, t_max: int) -> ScanQueue:
    """An empty device queue (all rows invalid)."""
    return ScanQueue(
        tokens=jnp.zeros((capacity, t_max), jnp.int32),
        prompt_len=jnp.ones((capacity,), jnp.int32),
        total_len=jnp.ones((capacity,), jnp.int32),
        rng=jnp.zeros((capacity, 2), jnp.uint32),
        temp=jnp.zeros((capacity,), jnp.float32),
        topk=jnp.zeros((capacity,), jnp.int32),
        head=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def make_done_buf(capacity: int, t_max: int, vocab: int) -> DoneBuf:
    """An empty retired-slot output buffer."""
    return DoneBuf(
        tokens=jnp.zeros((capacity, t_max), jnp.int32),
        prompt_logits=jnp.zeros((capacity, vocab), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        bad=jnp.zeros((capacity,), bool),
    )


def zero_slot_leaf(leaf, i):
    """Zero one slot's rows of a cache leaf.  Convention (every family):
    the only 1-D cache leaves are the per-slot ``pos``/``mem_len``
    counters; everything else stacks (L, B, ...) with the slot axis second.
    Memory-awareness: zeroing an encdec slot leaves ``mem_len`` at 0 —
    every cross-attention memory row masked — which decodes exactly as the
    zeroed ``mem_k``/``mem_v`` rows would (zero output), so a token-only
    request admitted after an encdec occupant can never see stale memory.
    ``admit_memory`` then overwrites the memory rows + ``mem_len`` for
    requests that DO carry encoder input."""
    if leaf.ndim == 1:
        return leaf.at[i].set(0)
    return leaf.at[:, i].set(jnp.zeros_like(leaf[:, i]))


def _zero_slot_leaf_masked(leaf, i, on):
    """``zero_slot_leaf`` under a traced predicate: when ``on`` is False
    the slot's rows are written back unchanged (an O(row) no-op, never an
    O(leaf) one — only slot ``i``'s rows are touched either way)."""
    if leaf.ndim == 1:
        return leaf.at[i].set(jnp.where(on, jnp.zeros_like(leaf[i]), leaf[i]))
    row = leaf[:, i]
    return leaf.at[:, i].set(jnp.where(on, jnp.zeros_like(row), row))


class GenState(NamedTuple):
    """Per-slot generation state the fused decode driver scans over.

    The device never hands control back to Python between tokens: prompt
    consumption, sampling, append — and, when a queue is attached, slot
    admission and retired-slot harvest — all happen inside the scan body,
    so a whole generation (or a continuous-batching chunk) is one dispatch.

    tokens      — (B, T_max) token buffer: prompt tokens up front, generated
                  tokens appended in place at the slot's position;
    prompt_len  — (B,) per-slot prompt length;
    total_len   — (B,) per-slot prompt_len + gen budget;
    active      — (B,) slots still consuming/producing (free slots idle with
                  frozen cache.pos — their lockstep compute is discarded);
    prompt_logits — (B, V) fp32 logits after each slot's last prompt token
                  (the verification comparison point of the python loop);
    rng         — (B, 2) uint32 per-slot sampling base keys.  The scan never
                  mutates them: the key for a slot's t-th generated token is
                  ``fold_in(rng[slot], t)``, a function of slot-local
                  progress only — so a request samples identically isolated
                  or staggered, whatever slot or step it lands on.
    temp / topk — (B,) fp32 / int32 per-slot sampling params, written at
                  admission alongside ``rng`` and read by the
                  ``Sampling(per_slot=True)`` driver mode (``topk == 0``
                  disables the top-k filter for that slot).  ``None`` on the
                  uniform-batch ``generate`` path, which samples under a
                  static engine-wide ``Sampling`` instead.
    queue / done — optional device-resident admission queue and retired-
                  slot output buffer (in-scan continuous batching); ``None``
                  on the uniform-batch path and under boundary admission.
    bad         — optional (B,) bool numeric-guard accumulator: set (and
                  never cleared until re-admission) once a slot's logits go
                  non-finite.  The slot keeps its deterministic retirement
                  step — the host-mirrored schedule must not observe NaNs —
                  and the flag rides out with the done flags at harvest, so
                  quarantine costs no extra readback.  ``None`` on the
                  uniform-batch path.
    """
    cache: object
    tokens: jax.Array
    prompt_len: jax.Array
    total_len: jax.Array
    active: jax.Array
    prompt_logits: jax.Array
    rng: jax.Array
    temp: Optional[jax.Array] = None
    topk: Optional[jax.Array] = None
    queue: Optional[ScanQueue] = None
    done: Optional[DoneBuf] = None
    bad: Optional[jax.Array] = None


def gen_init(cache, tokens, prompt_len, total_len, vocab: int,
             active=None, rng=None, temp=None, topk=None,
             queue: Optional[ScanQueue] = None,
             done: Optional[DoneBuf] = None,
             bad=None) -> GenState:
    """Pack a slot pool into a GenState (per-slot lengths may differ).

    ``temp``/``topk`` attach per-slot sampling params ((B,) arrays, used by
    ``Sampling(per_slot=True)``); ``queue``/``done`` attach the in-scan
    admission machinery; ``bad`` attaches the per-slot NaN/Inf logit guard.
    All default to None — the uniform-batch ``generate`` path carries none
    of them.
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    b = tokens.shape[0]
    prompt_len = jnp.broadcast_to(
        jnp.asarray(prompt_len, jnp.int32), (b,))
    total_len = jnp.broadcast_to(jnp.asarray(total_len, jnp.int32), (b,))
    if active is None:
        active = jnp.ones((b,), bool)
    if rng is None:
        rng = jnp.zeros((b, 2), jnp.uint32)
    return GenState(
        cache=cache,
        tokens=tokens,
        prompt_len=prompt_len,
        total_len=total_len,
        active=jnp.broadcast_to(jnp.asarray(active, bool), (b,)),
        prompt_logits=jnp.zeros((b, vocab), jnp.float32),
        rng=jnp.asarray(rng, jnp.uint32),
        temp=None if temp is None else jnp.asarray(temp, jnp.float32),
        topk=None if topk is None else jnp.asarray(topk, jnp.int32),
        queue=queue,
        done=done,
        bad=None if bad is None else jnp.asarray(bad, bool),
    )


def _scan_admit(state: GenState) -> GenState:
    """In-scan admission sweep (runs at the top of every ``gen_step`` when
    a queue is attached): fill free slots from the device queue, FIFO,
    lowest slot index first.  Admission copies the queue row into the slot
    (lengths, prompt row, rng, sampling params), zeroes the slot's cache
    rows, and activates it — the slot consumes its first prompt token in
    the very same step.  The whole sweep is skipped via ``lax.cond`` when
    nothing is admittable (no free slot or empty queue), so steady-state
    full-occupancy steps pay only the predicate.

    The host mirrors this arithmetic exactly (same FIFO order, same slot
    placement, same step) to track which request occupies which slot
    without reading the device.
    """
    b = state.tokens.shape[0]
    qcap = state.queue.tokens.shape[0]

    def sweep(s: GenState) -> GenState:
        q = s.queue
        cache, tokens, plog = s.cache, s.tokens, s.prompt_logits
        plen, tlen, act = s.prompt_len, s.total_len, s.active
        rng, temp, topk, bad = s.rng, s.temp, s.topk, s.bad
        head = q.head
        for i in range(b):
            admit = jnp.logical_and(~act[i], head < q.size)
            idx = jnp.clip(head, 0, qcap - 1)
            cache = jax.tree.map(
                lambda leaf, i=i, on=admit: _zero_slot_leaf_masked(
                    leaf, i, on),
                cache,
            )
            tokens = tokens.at[i].set(
                jnp.where(admit, q.tokens[idx], tokens[i]))
            plen = plen.at[i].set(jnp.where(admit, q.prompt_len[idx],
                                            plen[i]))
            tlen = tlen.at[i].set(jnp.where(admit, q.total_len[idx],
                                            tlen[i]))
            rng = rng.at[i].set(jnp.where(admit, q.rng[idx], rng[i]))
            temp = temp.at[i].set(jnp.where(admit, q.temp[idx], temp[i]))
            topk = topk.at[i].set(jnp.where(admit, q.topk[idx], topk[i]))
            plog = plog.at[i].set(
                jnp.where(admit, jnp.zeros_like(plog[i]), plog[i]))
            act = act.at[i].set(jnp.logical_or(admit, act[i]))
            if bad is not None:   # new occupant starts with a clean guard
                bad = bad.at[i].set(jnp.where(admit, False, bad[i]))
            head = head + admit.astype(jnp.int32)
        return s._replace(
            cache=cache, tokens=tokens, prompt_len=plen, total_len=tlen,
            active=act, prompt_logits=plog, rng=rng, temp=temp, topk=topk,
            queue=q._replace(head=head), bad=bad,
        )

    admittable = jnp.logical_and(state.queue.head < state.queue.size,
                                 jnp.any(~state.active))
    return jax.lax.cond(admittable, sweep, lambda s: s, state)


def _scan_harvest(state: GenState, retired: jax.Array) -> GenState:
    """Copy slots that retired THIS step into the done buffer (slot order),
    before a later in-scan admission can overwrite their token rows.
    Skipped via ``lax.cond`` on steps with no retirement."""
    b = state.tokens.shape[0]
    dcap = state.done.tokens.shape[0]

    def sweep(s: GenState) -> GenState:
        dt, dl, cnt = s.done.tokens, s.done.prompt_logits, s.done.count
        db = s.done.bad
        for i in range(b):
            r = retired[i]
            w = jnp.clip(cnt, 0, dcap - 1)
            dt = dt.at[w].set(jnp.where(r, s.tokens[i], dt[w]))
            dl = dl.at[w].set(jnp.where(r, s.prompt_logits[i], dl[w]))
            if db is not None and s.bad is not None:
                db = db.at[w].set(jnp.where(r, s.bad[i], db[w]))
            cnt = cnt + r.astype(jnp.int32)
        return s._replace(done=DoneBuf(dt, dl, cnt, db))

    return jax.lax.cond(jnp.any(retired), sweep, lambda s: s, state)


def gen_step(decode_step, params, state: GenState,
             sampling: Sampling = GREEDY) -> GenState:
    """One fused decode step over every slot (runs inside lax.scan).

    A slot at position p consumes tokens[p] — a prompt token while
    p < prompt_len (prefill-by-stepping), its own previous sample after —
    and samples the token for p+1 (greedy argmax, or temperature/top-k
    under the slot's own PRNG stream; per-slot params under
    ``Sampling(per_slot=True)``).  Inactive slots are frozen: their
    cache.pos is pinned so the batched decode_step re-writes the same cache
    row with the same values (idempotent), and their buffers are left
    untouched.  Every update is a masked select, so heterogeneous slots run
    in lockstep without branching.

    When ``state.queue`` is attached, the step opens with an in-scan
    admission sweep (free slots refill from the device queue and consume
    their first prompt token this very step); when ``state.done`` is
    attached, slots that retire this step are copied into the done buffer
    before the next step's admission can overwrite their rows.
    """
    if state.queue is not None:
        state = _scan_admit(state)
    cache = state.cache
    pos = cache.pos                                        # (B,) per-slot
    t_max = state.tokens.shape[1]
    cur = jnp.take_along_axis(
        state.tokens, jnp.clip(pos, 0, t_max - 1)[:, None], axis=1
    )                                                      # (B, 1)
    logits, cache = decode_step(params, cache, cur)
    adv = state.active
    cache = cache._replace(pos=jnp.where(adv, cache.pos, pos))
    newpos = cache.pos
    if sampling.per_slot:
        gen_idx = jnp.maximum(newpos - state.prompt_len, 0)
        keys = jax.vmap(jax.random.fold_in)(state.rng, gen_idx)
        nxt = sample_tokens_per_slot(logits, keys, state.temp, state.topk)
    elif sampling.temperature == 0.0:
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # greedy sample
    else:
        # key = fold_in(slot base key, # tokens this slot has generated) —
        # slot-local progress, so staggered == isolated holds under sampling
        gen_idx = jnp.maximum(newpos - state.prompt_len, 0)
        keys = jax.vmap(jax.random.fold_in)(state.rng, gen_idx)
        nxt = sample_tokens(logits, keys, sampling)
    widx = jnp.clip(newpos, 0, t_max - 1)
    write = adv & (newpos >= state.prompt_len) & (newpos < state.total_len)
    bidx = jnp.arange(state.tokens.shape[0])
    old = state.tokens[bidx, widx]
    tokens = state.tokens.at[bidx, widx].set(jnp.where(write, nxt, old))
    at_prompt_end = adv & (pos == state.prompt_len - 1)
    prompt_logits = jnp.where(
        at_prompt_end[:, None], logits.astype(jnp.float32),
        state.prompt_logits,
    )
    # the step that writes the slot's last token (index total_len-1) retires it
    active = adv & (newpos <= state.total_len - 2)
    bad = state.bad
    if bad is not None:
        # numeric guard: one isfinite reduction folded into the step.  The
        # flag only ACCUMULATES — the slot still runs to its scheduled
        # retirement (masked lockstep makes the extra steps free), because
        # retiring early would desync the host-mirrored schedule.  It rides
        # out with the done flags at harvest: no extra readback.
        finite = jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)
        bad = bad | (adv & ~finite)
    state = state._replace(
        cache=cache, tokens=tokens, active=active,
        prompt_logits=prompt_logits, bad=bad,
    )
    if state.done is not None:
        state = _scan_harvest(state, adv & ~active)
    return state


def gen_scan(decode_step, params, state: GenState, n_steps: int,
             sampling: Sampling = GREEDY) -> GenState:
    """``n_steps`` fused decode steps as one scanned computation — the
    while_loop-style driver body (fixed trip count, so it scans)."""
    def body(s, _):
        return gen_step(decode_step, params, s, sampling), None
    state, _ = jax.lax.scan(body, state, None, length=n_steps)
    return state


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def tt_native_params(compressed, core_dtype=None, family: Optional[str] = None,
                     quant: Optional[str] = None,
                     quant_calib: str = "absmax"):
    """TTCompressor payload → TT-native serving params.

    Layer-stacked matmul weights whose TT payload maps cleanly onto the
    (stack[, experts], in, out) axes become ``TTLinear`` leaves — served
    straight from cores.  Eligibility comes from the per-family rule
    registry (``register_tt_serve_rules``): every family in the zoo —
    transformer (dense/moe/vlm), encdec, ssm, hybrid — registers its own
    weight paths, including MoE expert banks (expert-axis TTLinear, served
    via the expert-batched chain).  Everything else (embeddings, norms,
    routers, raw-routed and padded params) reconstructs exactly as the
    Fig. 1 receiving node does today.  The result drops into
    ``decode_step`` / ``forward`` unchanged; peak weight bytes shrink by
    the payload's compression ratio on the converted leaves.

    family: which family's rules to apply (``cfg.family``); None applies
    the union over all registered families — path namespaces are disjoint
    across the zoo, so this is safe when the payload's origin is unknown.

    core_dtype: resident-core storage dtype; ``None`` (the sentinel — an
    explicit dtype is never second-guessed, however it compares) stores
    each leaf's cores in its original weight dtype (bf16 for the zoo) —
    the same rounding reconstruct-then-serve applies to the dense matrix.

    quant: integer storage format name (``"int8"``) or None.  When set,
    every TTLinear leaf is symmetrically quantized (per-core scales,
    per-row lead scales — ``core/tt_linear.quantize_tt``) after conversion;
    the fused kernels dequantize in-VMEM at apply time, so the serving
    contract (``decode_step``/``forward`` signatures, staggered == isolated
    under continuous batching) is unchanged — only logits move within the
    quantization error bound.  quant_calib: ``"absmax"`` (default) or
    ``"pXX"`` percentile clipping, forwarded to the calibrator.
    """
    from repro.core import compression as _comp
    from repro.core import tt_linear as _ttl

    rules = tt_serve_rules(family)
    qdt = None if quant is None else _ttl.quant_dtype(quant)

    def is_cp(x):
        return isinstance(x, _comp.CompressedParam)

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        compressed, is_leaf=is_cp
    )
    leaves = []
    for path, c in flat:
        leaf = None
        if is_cp(c) and c.kind == "tt" and c.crop_dims is None:
            name = _path_str(path)
            for rule in rules:
                if rule.pattern.search(name):
                    leaf = _ttl.tt_linear_from_tt(
                        c.tt, c.orig_shape,
                        stack=rule.stack, in_ndim=rule.in_ndim,
                        dtype=c.orig_dtype,
                        core_dtype=(c.orig_dtype if core_dtype is None
                                    else core_dtype),
                        experts=rule.experts,
                    )
                    break
        if leaf is None:
            leaf = _comp.decompress_param(c) if is_cp(c) else c
        elif qdt is not None:
            leaf = _ttl.quantize_tt(leaf, dtype=qdt, calib=quant_calib)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def logit_parity(a: jax.Array, b: jax.Array) -> Tuple[float, float, float]:
    """(max|a−b|, |b| scale, argmax agreement) — the single tolerance
    surface every TT-native-vs-reconstruct comparison (serve --verify,
    benchmarks/tt_serve, examples, tests) shares.  The accepted bound for
    same-cores comparisons is ``max_diff <= max(0.05 * scale, 1e-3)``:
    both paths contract identical cores in identical order, so only
    bf16-level rounding may differ."""
    d = float(jnp.abs(a - b).max())
    scale = float(jnp.abs(b).max()) + 1e-9
    agree = float(jnp.mean(
        (jnp.argmax(a, -1) == jnp.argmax(b, -1)).astype(jnp.float32)
    ))
    return d, scale, agree


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    """Scaled-normal init (truncated at 3σ), σ = 1/√fan_in."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (
        jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std
    ).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (
        jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * 0.02
    ).astype(dtype)


def stacked(keys, fn):
    """vmap an initializer over a leading layer axis."""
    return jax.vmap(fn)(keys)


def cross_entropy_loss(
    logits: jax.Array,           # (B, S, V)
    labels: jax.Array,           # (B, S)
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def unembed(x: jax.Array, embed: jax.Array, softcap: Optional[float] = None,
            real_vocab: Optional[int] = None):
    """Logits = x @ Eᵀ (fp32), optional tanh softcap.

    real_vocab: when the table is padded (opt_pad_vocab), logits for the
    padding rows are masked to -inf so CE/argmax never select them.
    """
    # the embedding table is documented dense-resident (tied unembed; the
    # TT policy never compresses it), so this transposed lookup is the one
    # weight einsum with no dispatch to route through
    # lint: skip[AST001]
    logits = jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), embed.astype(jnp.float32)
    )
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if real_vocab is not None and real_vocab < embed.shape[0]:
        pad_mask = jnp.arange(embed.shape[0]) >= real_vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits
