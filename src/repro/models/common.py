"""Shared model building blocks: norms, RoPE, embeddings, initializers.

Conventions (used by every arch in the zoo):
  * parameters are nested dicts; per-layer tensors are STACKED on a leading
    (num_layers,) axis so layers run under ``jax.lax.scan`` — this keeps HLO
    size and compile time independent of depth (essential for the 512-way
    dry-run compiles).
  * compute dtype is bf16; norms, softmax, and losses run fp32.
  * initializers take an explicit key and are only materialized for reduced
    (smoke-test) configs and the ~100M example — full-size configs are
    touched exclusively through ``jax.eval_shape``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def pin_batch(x, cfg):
    """opt_batch_pin: re-assert batch-dim data sharding inside scan bodies
    (GSPMD can drop it across scan/jvp boundaries, silently replicating the
    batch; see EXPERIMENTS.md §Perf seamless)."""
    if getattr(cfg, "opt_batch_pin", False):
        from repro.launch import sharding as _shd
        return _shd.act_constraint(x, "data", *([None] * (x.ndim - 1)))
    return x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,                # (..., S, H, D)
    positions: jax.Array,        # (..., S)
    theta: float,
) -> jax.Array:
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activate(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {act}")


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    """Scaled-normal init (truncated at 3σ), σ = 1/√fan_in."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (
        jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std
    ).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (
        jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * 0.02
    ).astype(dtype)


def stacked(keys, fn):
    """vmap an initializer over a leading layer axis."""
    return jax.vmap(fn)(keys)


def cross_entropy_loss(
    logits: jax.Array,           # (B, S, V)
    labels: jax.Array,           # (B, S)
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def unembed(x: jax.Array, embed: jax.Array, softcap: Optional[float] = None,
            real_vocab: Optional[int] = None):
    """Logits = x @ Eᵀ (fp32), optional tanh softcap.

    real_vocab: when the table is padded (opt_pad_vocab), logits for the
    padding rows are masked to -inf so CE/argmax never select them.
    """
    logits = jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), embed.astype(jnp.float32)
    )
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if real_vocab is not None and real_vocab < embed.shape[0]:
        pad_mask = jnp.arange(embed.shape[0]) >= real_vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits
