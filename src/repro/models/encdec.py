"""Encoder–decoder backbone (seamless-m4t-large-v2 assignment).

The multimodal frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, D) as the encoder input.
Encoder: bidirectional self-attention layers (scanned).  Decoder: causal
self-attention + cross-attention to the encoder memory (scanned).
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common
from repro.models import mlp as mlp_mod


class EncLayerParams(NamedTuple):
    ln1: jax.Array
    attn: attn.AttnParams
    ln2: jax.Array
    mlp: mlp_mod.MLPParams


class DecLayerParams(NamedTuple):
    ln1: jax.Array
    self_attn: attn.AttnParams
    ln_x: jax.Array
    cross_attn: attn.AttnParams
    ln2: jax.Array
    mlp: mlp_mod.MLPParams


class EncDecParams(NamedTuple):
    embed: jax.Array              # (V, D) decoder token embeddings
    enc_layers: EncLayerParams    # stacked (Le, ...)
    enc_norm: jax.Array
    dec_layers: DecLayerParams    # stacked (Ld, ...)
    final_norm: jax.Array


def init(key, cfg) -> EncDecParams:
    dt = common.cdtype(cfg)
    ks = jax.random.split(key, 6)
    le, ld = cfg.enc_layers, cfg.num_layers
    enc = EncLayerParams(
        ln1=jnp.zeros((le, cfg.d_model), dt),
        attn=attn.init_attn(ks[0], cfg, layers=le),
        ln2=jnp.zeros((le, cfg.d_model), dt),
        mlp=mlp_mod.init_mlp(ks[1], cfg, layers=le),
    )
    dec = DecLayerParams(
        ln1=jnp.zeros((ld, cfg.d_model), dt),
        self_attn=attn.init_attn(ks[2], cfg, layers=ld),
        ln_x=jnp.zeros((ld, cfg.d_model), dt),
        cross_attn=attn.init_attn(ks[3], cfg, layers=ld),
        ln2=jnp.zeros((ld, cfg.d_model), dt),
        mlp=mlp_mod.init_mlp(ks[4], cfg, layers=ld),
    )
    return EncDecParams(
        embed=common.embed_init(ks[5], (cfg.padded_vocab_size, cfg.d_model), dt),
        enc_layers=enc,
        enc_norm=jnp.zeros((cfg.d_model,), dt),
        dec_layers=dec,
        final_norm=jnp.zeros((cfg.d_model,), dt),
    )


def encode(params: EncDecParams, frames: jax.Array, cfg,
           impl: str = "xla") -> jax.Array:
    """frames: (B, S_enc, D) precomputed frontend embeddings (stub input)."""
    x = frames.astype(common.cdtype(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, lp: EncLayerParams):
        def blk(hh, lp):
            if getattr(cfg, "opt_batch_pin", False):
                from repro.launch import sharding as _shd
                hh = _shd.act_constraint(hh, "data", None, None)
            hn = common.rms_norm(hh, lp.ln1, cfg.norm_eps)
            q, k, v = attn.qkv_project(hn, lp.attn, cfg, positions)
            o = attn.cross_attend(q, k, v, cfg)   # full bidirectional
            hh = hh + common.dense_apply(o, lp.attn.wo, in_ndim=2)
            hn = common.rms_norm(hh, lp.ln2, cfg.norm_eps)
            return (hh + mlp_mod.mlp_apply(hn, lp.mlp, cfg.act)).astype(hh.dtype)
        fn = jax.checkpoint(blk) if cfg.remat else blk
        return fn(h, lp), None

    x, _ = common.tt_scan(body, x, params.enc_layers, length=cfg.enc_layers)
    return common.rms_norm(x, params.enc_norm, cfg.norm_eps)


def _dec_block(h, lp: DecLayerParams, memory, cfg, positions, mem_positions,
               impl):
    if getattr(cfg, "opt_batch_pin", False):
        from repro.launch import sharding as _shd
        h = _shd.act_constraint(h, "data", None, None)
        memory = _shd.act_constraint(memory, "data", None, None)
    hn = common.rms_norm(h, lp.ln1, cfg.norm_eps)
    q, k, v = attn.qkv_project(hn, lp.self_attn, cfg, positions)
    o = attn.causal_attend(q, k, v, cfg, impl=impl)
    h = h + common.dense_apply(o, lp.self_attn.wo, in_ndim=2)
    # cross attention to encoder memory
    hn = common.rms_norm(h, lp.ln_x, cfg.norm_eps)
    q = common.dense_apply(hn, lp.cross_attn.wq)
    km = common.dense_apply(memory, lp.cross_attn.wk)
    vm = common.dense_apply(memory, lp.cross_attn.wv)
    o = attn.cross_attend(q, km, vm, cfg)
    h = h + common.dense_apply(o, lp.cross_attn.wo, in_ndim=2)
    hn = common.rms_norm(h, lp.ln2, cfg.norm_eps)
    return (h + mlp_mod.mlp_apply(hn, lp.mlp, cfg.act)).astype(h.dtype)


def decode_train(params: EncDecParams, tokens, memory, cfg,
                 impl: str = "xla") -> jax.Array:
    x = params.embed[tokens].astype(common.cdtype(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mem_positions = jnp.broadcast_to(
        jnp.arange(memory.shape[1]), (b, memory.shape[1])
    )

    def body(h, lp):
        fn = functools.partial(
            _dec_block, memory=memory, cfg=cfg, positions=positions,
            mem_positions=mem_positions, impl=impl,
        )
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn(h, lp), None

    x, _ = common.tt_scan(body, x, params.dec_layers, length=cfg.num_layers)
    return common.rms_norm(x, params.final_norm, cfg.norm_eps)


def loss_fn(params, batch: Dict, cfg, impl: str = "xla"):
    memory = encode(params, batch["frames"], cfg, impl=impl)
    hidden = decode_train(params, batch["tokens"], memory, cfg, impl=impl)
    logits = common.unembed(hidden, params.embed, cfg.logit_softcap,
                            real_vocab=cfg.vocab_size)
    loss = common.cross_entropy_loss(
        logits, batch["labels"], batch.get("mask")
    )
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Decode with cache: self-attn KV cache + precomputed cross-attn memory KV
# ---------------------------------------------------------------------------

class EncDecCache(NamedTuple):
    k: jax.Array                  # (Ld, B, S_max, Hkv, Dh) self-attn
    v: jax.Array
    mem_k: jax.Array              # (Ld, B, S_enc, Hkv, Dh) cross-attn (fixed)
    mem_v: jax.Array
    mem_len: jax.Array            # (B,) int32 valid memory rows per slot
    pos: jax.Array                # (B,) int32 per-slot (scalar also accepted)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    ld = cfg.num_layers
    shape = (ld, batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    mshape = (ld, batch, cfg.frontend_len, cfg.num_kv_heads,
              cfg.resolved_head_dim)
    return EncDecCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        mem_k=jnp.zeros(mshape, dtype), mem_v=jnp.zeros(mshape, dtype),
        # all rows valid by default: zero memory under a full mask attends
        # uniformly over zero V rows — exactly zero, the legacy behaviour
        mem_len=jnp.full((batch,), cfg.frontend_len, jnp.int32),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def project_memory_kv(params: EncDecParams, memory, cfg):
    """Per-layer cross-attn K/V of an encoder memory: (Ld, B, S, Hkv, Dh)
    pair — the one projection every memory-population path shares."""
    def proj(lp: DecLayerParams):
        km = common.dense_apply(memory, lp.cross_attn.wk)
        vm = common.dense_apply(memory, lp.cross_attn.wv)
        return km, vm
    if common.layers_have_tt(params.dec_layers):
        # TTLinear leaves can't ride a vmap over the stacked tree (cores
        # carry no layer axis) — map the layer index and gather instead
        return jax.lax.map(
            lambda i: proj(common.layer_at(params.dec_layers, i)),
            jnp.arange(cfg.num_layers),
        )
    return jax.vmap(proj)(params.dec_layers)


def precompute_memory_cache(params: EncDecParams, memory, cfg,
                            cache: EncDecCache) -> EncDecCache:
    """Project the encoder memory into per-layer cross-attn K/V once."""
    km, vm = project_memory_kv(params, memory, cfg)
    return cache._replace(
        mem_k=km.astype(cache.mem_k.dtype),
        mem_v=vm.astype(cache.mem_v.dtype),
        mem_len=jnp.full((memory.shape[0],), memory.shape[1], jnp.int32),
    )


def encode_memory(params: EncDecParams, src_tokens, cfg):
    """Source tokens → per-layer cross-attn memory K/V.

    The multimodal frontend is a STUB (see module docstring): source tokens
    embed through the tied decoder table to stand in for frame embeddings,
    then run the bidirectional encoder.  Returns the (Ld, B, S_src, Hkv,
    Dh) K/V pair ready to drop into ``EncDecCache.mem_k``/``mem_v`` rows.
    """
    frames = params.embed[src_tokens].astype(common.cdtype(cfg))
    memory = encode(params, frames, cfg)
    return project_memory_kv(params, memory, cfg)


def populate_memory(params: EncDecParams, cache: EncDecCache, src_tokens,
                    cfg) -> EncDecCache:
    """Whole-batch memory population (isolated ``generate()`` front door):
    every row encodes its own source; rows past ``S_src`` stay zero and are
    masked out by ``mem_len``."""
    km, vm = encode_memory(params, src_tokens, cfg)
    s = km.shape[2]
    return cache._replace(
        mem_k=cache.mem_k.at[:, :, :s].set(km.astype(cache.mem_k.dtype)),
        mem_v=cache.mem_v.at[:, :, :s].set(vm.astype(cache.mem_v.dtype)),
        mem_len=jnp.full((src_tokens.shape[0],), s, jnp.int32),
    )


def admit_memory(params: EncDecParams, cache: EncDecCache, slot, src_tokens,
                 cfg) -> EncDecCache:
    """One slot's encoder memory at admission: encode the request's source
    (batch of one), project cross-attn K/V, and write ONLY that slot's
    ``mem_k``/``mem_v`` rows + ``mem_len`` — the slot-granular counterpart
    of ``populate_memory`` that lets the continuous-batching engine run
    encode per request instead of zeroing the memory away."""
    km, vm = encode_memory(params, src_tokens[None, :], cfg)
    s = km.shape[2]
    return cache._replace(
        mem_k=cache.mem_k.at[:, slot, :s].set(
            km[:, 0].astype(cache.mem_k.dtype)),
        mem_v=cache.mem_v.at[:, slot, :s].set(
            vm[:, 0].astype(cache.mem_v.dtype)),
        mem_len=cache.mem_len.at[slot].set(s),
    )


def decode_step(params: EncDecParams, cache: EncDecCache, tokens, cfg):
    x = params.embed[tokens].astype(common.cdtype(cfg))
    pos = cache.pos
    b = x.shape[0]
    positions = jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (b, 1))

    def body(h, lp, k_c, v_c, mk, mv):
        hn = common.rms_norm(h, lp.ln1, cfg.norm_eps)
        q, k_new, v_new = attn.qkv_project(hn, lp.self_attn, cfg, positions)
        k_c, v_c = attn.cache_update(k_c, v_c, k_new, v_new, pos)
        o = attn.decode_attend(q, k_c, v_c, pos, cfg)
        h = h + common.dense_apply(o, lp.self_attn.wo, in_ndim=2)
        hn = common.rms_norm(h, lp.ln_x, cfg.norm_eps)
        q = common.dense_apply(hn, lp.cross_attn.wq)
        o = attn.cross_attend(q, mk, mv, cfg, mem_len=cache.mem_len)
        h = h + common.dense_apply(o, lp.cross_attn.wo, in_ndim=2)
        hn = common.rms_norm(h, lp.ln2, cfg.norm_eps)
        h = (h + mlp_mod.mlp_apply(hn, lp.mlp, cfg.act)).astype(h.dtype)
        return h, (k_c, v_c)

    x, (k_all, v_all) = common.tt_scan(
        body, x, params.dec_layers,
        xs=(cache.k, cache.v, cache.mem_k, cache.mem_v),
        length=cfg.num_layers,
    )
    hidden = common.rms_norm(x, params.final_norm, cfg.norm_eps)
    logits = common.unembed(hidden, params.embed, cfg.logit_softcap,
                            real_vocab=cfg.vocab_size)
    return logits[:, 0, :], cache._replace(k=k_all, v=v_all, pos=pos + 1)


def prefill(params, batch: Dict, cfg, impl: str = "xla"):
    memory = encode(params, batch["frames"], cfg, impl=impl)
    hidden = decode_train(params, batch["tokens"], memory, cfg, impl=impl)
    logits = common.unembed(hidden[:, -1:, :], params.embed,
                            cfg.logit_softcap, real_vocab=cfg.vocab_size)
    return logits[:, 0, :]


# TT-native serving rules: every encoder/decoder matmul weight — self- and
# cross-attention projections and both MLP stacks — serves from cores.
common.register_tt_serve_rules("encdec", [
    common.TTServeRule(r"^enc_layers\.attn\.w[qkv]$", in_ndim=1),
    common.TTServeRule(r"^enc_layers\.attn\.wo$", in_ndim=2),
    common.TTServeRule(r"^enc_layers\.mlp\.w_(gate|up|down)$", in_ndim=1),
    common.TTServeRule(r"^dec_layers\.(self|cross)_attn\.w[qkv]$", in_ndim=1),
    common.TTServeRule(r"^dec_layers\.(self|cross)_attn\.wo$", in_ndim=2),
    common.TTServeRule(r"^dec_layers\.mlp\.w_(gate|up|down)$", in_ndim=1),
])
