"""Unified Model API over the 10-arch zoo.

``build(cfg)`` returns a ``Model`` with a uniform surface:
  init(key) -> params
  loss_fn(params, batch) -> (loss, metrics)          [train shapes]
  prefill(params, batch) -> last-token logits        [prefill shapes]
  init_cache(batch, max_len) -> cache
  decode_step(params, cache, tokens) -> (logits, cache)   [decode shapes]
  train_batch_spec / prefill_batch_spec / decode_batch_spec — ShapeDtypeStructs
    for the dry-run (frontend stubs appear here as precomputed embeddings).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as encdec_mod
from repro.models import mamba2 as mamba_mod
from repro.models import rglru as rglru_mod
from repro.models import transformer as tfm


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable
    train_batch_spec: Callable
    prefill_batch_spec: Callable
    decode_batch_spec: Callable
    # encoder-memory hooks (encdec only; None elsewhere — the serving layer
    # keys "does this family take encoder input" off their presence):
    #   populate_memory(params, cache, src_tokens) -> cache   [whole batch]
    #   admit_memory(params, cache, slot, src_row) -> cache   [one slot]
    populate_memory: Optional[Callable] = None
    admit_memory: Optional[Callable] = None


def _tok_spec(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _lm_specs(cfg):
    def train(b, s):
        return {"tokens": _tok_spec(b, s), "labels": _tok_spec(b, s)}

    def prefill(b, s):
        return {"tokens": _tok_spec(b, s)}

    def decode(b):
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    return train, prefill, decode


def _build_transformer(cfg: ModelConfig) -> Model:
    train_spec, prefill_spec, decode_spec = _lm_specs(cfg)

    def loss(params, batch, impl="xla"):
        return tfm.loss_fn(params, batch, cfg, impl=impl)

    def prefill(params, batch, impl="xla"):
        return tfm.prefill(
            params, batch["tokens"], cfg,
            prefix_embeds=batch.get("prefix_embeds"), impl=impl,
        )

    return Model(
        cfg=cfg,
        init=functools.partial(tfm.init, cfg=cfg),
        loss_fn=loss,
        prefill=prefill,
        init_cache=functools.partial(tfm.init_cache, cfg),
        decode_step=lambda p, c, t: tfm.decode_step(p, c, t, cfg),
        train_batch_spec=train_spec,
        prefill_batch_spec=prefill_spec,
        decode_batch_spec=decode_spec,
    )


def _build_vlm(cfg: ModelConfig) -> Model:
    """Pixtral backbone: decoder LM consuming [patch embeds | tokens]."""
    base = _build_transformer(cfg)
    p = cfg.frontend_len
    d = cfg.d_model

    def train_spec(b, s):
        # text length shrinks so total backbone sequence stays s
        return {
            "tokens": _tok_spec(b, s - p),
            "labels": _tok_spec(b, s - p),
            "prefix_embeds": jax.ShapeDtypeStruct((b, p, d), jnp.bfloat16),
        }

    def prefill_spec(b, s):
        return {
            "tokens": _tok_spec(b, s - p),
            "prefix_embeds": jax.ShapeDtypeStruct((b, p, d), jnp.bfloat16),
        }

    base.train_batch_spec = train_spec
    base.prefill_batch_spec = prefill_spec
    return base


def _build_mamba(cfg: ModelConfig) -> Model:
    train_spec, prefill_spec, decode_spec = _lm_specs(cfg)
    return Model(
        cfg=cfg,
        init=functools.partial(mamba_mod.init, cfg=cfg),
        loss_fn=lambda p, b, impl="xla": mamba_mod.loss_fn(p, b, cfg, impl=impl),
        prefill=lambda p, b, impl="xla": mamba_mod.prefill(
            p, b["tokens"], cfg, impl=impl
        ),
        init_cache=functools.partial(mamba_mod.init_cache, cfg),
        decode_step=lambda p, c, t: mamba_mod.decode_step(p, c, t, cfg),
        train_batch_spec=train_spec,
        prefill_batch_spec=prefill_spec,
        decode_batch_spec=decode_spec,
    )


def _build_griffin(cfg: ModelConfig) -> Model:
    train_spec, prefill_spec, decode_spec = _lm_specs(cfg)
    return Model(
        cfg=cfg,
        init=functools.partial(rglru_mod.init, cfg=cfg),
        loss_fn=lambda p, b, impl="xla": rglru_mod.loss_fn(p, b, cfg, impl=impl),
        prefill=lambda p, b, impl="xla": rglru_mod.prefill(
            p, b["tokens"], cfg, impl=impl
        ),
        init_cache=functools.partial(rglru_mod.init_cache, cfg),
        decode_step=lambda p, c, t: rglru_mod.decode_step(p, c, t, cfg),
        train_batch_spec=train_spec,
        prefill_batch_spec=prefill_spec,
        decode_batch_spec=decode_spec,
    )


def _build_encdec(cfg: ModelConfig) -> Model:
    d = cfg.d_model
    se = cfg.frontend_len

    def train_spec(b, s):
        return {
            "tokens": _tok_spec(b, s),
            "labels": _tok_spec(b, s),
            "frames": jax.ShapeDtypeStruct((b, se, d), jnp.bfloat16),
        }

    def prefill_spec(b, s):
        return {
            "tokens": _tok_spec(b, s),
            "frames": jax.ShapeDtypeStruct((b, se, d), jnp.bfloat16),
        }

    def decode_spec(b):
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    return Model(
        cfg=cfg,
        init=functools.partial(encdec_mod.init, cfg=cfg),
        loss_fn=lambda p, b, impl="xla": encdec_mod.loss_fn(p, b, cfg, impl=impl),
        prefill=lambda p, b, impl="xla": encdec_mod.prefill(p, b, cfg, impl=impl),
        init_cache=functools.partial(encdec_mod.init_cache, cfg),
        decode_step=lambda p, c, t: encdec_mod.decode_step(p, c, t, cfg),
        train_batch_spec=train_spec,
        prefill_batch_spec=prefill_spec,
        decode_batch_spec=decode_spec,
        populate_memory=lambda p, c, s: encdec_mod.populate_memory(
            p, c, s, cfg),
        admit_memory=lambda p, c, i, s: encdec_mod.admit_memory(
            p, c, i, s, cfg),
    )


_BUILDERS = {
    "dense": _build_transformer,
    "moe": _build_transformer,
    "ssm": _build_mamba,
    "hybrid": _build_griffin,
    "encdec": _build_encdec,
    "vlm": _build_vlm,
}


def build(cfg: ModelConfig) -> Model:
    return _BUILDERS[cfg.family](cfg)
