"""Decoder-only transformer LM — covers the dense, MoE, local/global, and
VLM-backbone architectures of the zoo (qwen1.5/qwen3/gemma3/olmoe/dbrx/
pixtral).

Layers are scanned (stacked params); per-layer heterogeneity that doesn't
change parameter shapes (gemma3's 5:1 local:global attention) is expressed
as a scanned boolean flag so a single homogeneous scan body serves every
layer.  Extra frontend inputs (pixtral patch embeddings) are prepended as
precomputed embeddings — the frontend itself is a stub per the assignment.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common
from repro.models import mlp as mlp_mod


class LayerParams(NamedTuple):
    attn: attn.AttnParams
    mlp: Optional[mlp_mod.MLPParams]
    moe: Optional[mlp_mod.MoEParams]
    ln1: jax.Array
    ln2: jax.Array


class TransformerParams(NamedTuple):
    embed: jax.Array                      # (V, D)
    layers: LayerParams                   # stacked (L, ...)
    final_norm: jax.Array                 # (D,)
    lm_head: Optional[jax.Array]          # (V, D) when untied


def init(key, cfg) -> TransformerParams:
    l = cfg.num_layers
    ks = jax.random.split(key, 5)
    dt = common.cdtype(cfg)
    layers = LayerParams(
        attn=attn.init_attn(ks[0], cfg, layers=l),
        mlp=(None if cfg.moe else mlp_mod.init_mlp(ks[1], cfg, layers=l)),
        moe=(mlp_mod.init_moe(ks[1], cfg, layers=l) if cfg.moe else None),
        ln1=jnp.zeros((l, cfg.d_model), dt),
        ln2=jnp.zeros((l, cfg.d_model), dt),
    )
    return TransformerParams(
        embed=common.embed_init(ks[2], (cfg.padded_vocab_size, cfg.d_model), dt),
        layers=layers,
        final_norm=jnp.zeros((cfg.d_model,), dt),
        lm_head=(
            None if cfg.tie_embeddings
            else common.embed_init(ks[3], (cfg.padded_vocab_size, cfg.d_model), dt)
        ),
    )


def _layer_flags(cfg) -> jax.Array:
    """Per-layer is_global flag (gemma3 pattern: every Nth layer global,
    counting from the Nth; all-global when no window is configured)."""
    if cfg.window is None or cfg.global_every is None:
        return jnp.ones((cfg.num_layers,), bool)
    # iota, not jnp.asarray(np.arange(...)): converting a concrete numpy
    # array under trace binds a device_put primitive per step (PRG002)
    idx = jnp.arange(cfg.num_layers)
    return (idx + 1) % cfg.global_every == 0


def _block(x, lp: LayerParams, is_global, cfg, positions, impl):
    x = common.pin_batch(x, cfg)
    h = common.rms_norm(x, lp.ln1, cfg.norm_eps)
    q, k, v = attn.qkv_project(h, lp.attn, cfg, positions)
    o = attn.causal_attend(
        q, k, v, cfg, window=cfg.window, is_global=is_global, impl=impl
    )
    x = x + common.dense_apply(o, lp.attn.wo, in_ndim=2)
    h = common.rms_norm(x, lp.ln2, cfg.norm_eps)
    if cfg.moe is not None:
        f = mlp_mod.moe_apply(h, lp.moe, cfg)
    else:
        f = mlp_mod.mlp_apply(h, lp.mlp, cfg.act)
    return (x + f).astype(x.dtype)


def forward(
    params: TransformerParams,
    tokens: jax.Array,                    # (B, S) int32
    cfg,
    prefix_embeds: Optional[jax.Array] = None,   # (B, P, D) frontend stub
    impl: str = "xla",
) -> jax.Array:
    """Returns final hidden states (B, S(+P), D)."""
    x = params.embed[tokens].astype(common.cdtype(cfg))
    if cfg.family == "dense" and cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate(
            [prefix_embeds.astype(x.dtype), x], axis=1
        )
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    flags = _layer_flags(cfg)

    fn = functools.partial(_block, cfg=cfg, positions=positions, impl=impl)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    # TT-aware layer scan (common.tt_scan): TT-native weights scan the
    # layer index and gather lead vectors in-body; cores stay closure
    # constants the scan must not slice.
    x, _ = common.tt_scan(
        lambda h, lp, is_global: (fn(h, lp, is_global), None),
        x, params.layers, xs=(flags,), length=cfg.num_layers,
    )
    return common.rms_norm(x, params.final_norm, cfg.norm_eps)


def logits_fn(params: TransformerParams, hidden: jax.Array, cfg):
    table = params.lm_head if params.lm_head is not None else params.embed
    return common.unembed(hidden, table, cfg.logit_softcap,
                          real_vocab=cfg.vocab_size)


def loss_fn(
    params: TransformerParams,
    batch: Dict[str, jax.Array],
    cfg,
    impl: str = "xla",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    prefix = batch.get("prefix_embeds")
    hidden = forward(params, batch["tokens"], cfg, prefix_embeds=prefix,
                     impl=impl)
    if prefix is not None:
        hidden = hidden[:, prefix.shape[1]:, :]
    logits = logits_fn(params, hidden, cfg)
    loss = common.cross_entropy_loss(
        logits, batch["labels"], batch.get("mask")
    )
    metrics = {"loss": loss}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    k: jax.Array                          # (L, B, S_max, Hkv, Dh)
    v: jax.Array
    pos: jax.Array                        # (B,) int32 — per-slot next write


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (
        cfg.num_layers, batch, max_len, cfg.num_kv_heads,
        cfg.resolved_head_dim,
    )
    # pos is PER-SLOT (B,): every batch row advances independently, the
    # contract the continuous-batching engine admits/retires slots under.
    # decode_step also accepts a scalar pos (legacy lockstep caches).
    return DecodeCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def decode_step(
    params: TransformerParams,
    cache: DecodeCache,
    tokens: jax.Array,                    # (B, 1)
    cfg,
) -> Tuple[jax.Array, DecodeCache]:
    """One token in, logits out; cache updated at cache.pos."""
    x = params.embed[tokens].astype(common.cdtype(cfg))
    if cfg.family == "dense" and cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    b = x.shape[0]
    pos = cache.pos
    positions = jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (b, 1))
    flags = _layer_flags(cfg)

    def step(h, lp, is_global, k_c, v_c):
        hh = common.rms_norm(h, lp.ln1, cfg.norm_eps)
        q, k_new, v_new = attn.qkv_project(hh, lp.attn, cfg, positions)
        k_c, v_c = attn.cache_update(k_c, v_c, k_new, v_new, pos)
        o = attn.decode_attend(
            q, k_c, v_c, pos, cfg, window=cfg.window, is_global=is_global
        )
        h = h + common.dense_apply(o, lp.attn.wo, in_ndim=2)
        hh = common.rms_norm(h, lp.ln2, cfg.norm_eps)
        if cfg.moe is not None:
            f = mlp_mod.moe_apply(hh, lp.moe, cfg)
        else:
            f = mlp_mod.mlp_apply(hh, lp.mlp, cfg.act)
        return (h + f).astype(h.dtype), (k_c, v_c)

    # TT-native decode: weights never leave TT form — common.tt_scan
    # carries only the layer index; cores are closure constants
    x, (k_all, v_all) = common.tt_scan(
        step, x, params.layers, xs=(flags, cache.k, cache.v),
        length=cfg.num_layers,
    )
    hidden = common.rms_norm(x, params.final_norm, cfg.norm_eps)
    logits = logits_fn(params, hidden, cfg)
    return logits[:, 0, :], DecodeCache(k=k_all, v=v_all, pos=pos + 1)


def prefill(
    params: TransformerParams,
    tokens: jax.Array,                    # (B, S)
    cfg,
    prefix_embeds: Optional[jax.Array] = None,
    impl: str = "xla",
) -> jax.Array:
    """Prefill pass: returns last-position logits (cache fill elided in the
    dry-run shape cell — prefill cost is the forward itself)."""
    hidden = forward(params, tokens, cfg, prefix_embeds=prefix_embeds,
                     impl=impl)
    logits = logits_fn(params, hidden[:, -1:, :], cfg)
    return logits[:, 0, :]


# ---------------------------------------------------------------------------
# TT-native serving rules (registered beside the model, per family)
# ---------------------------------------------------------------------------
# MoE expert banks (L, E, D, F) use stack=2/experts=1: both leading axes
# fold into the lead table but the expert mode stays a batch axis, served
# by the expert-batched chain through ``common.expert_apply``.
_TT_RULES = [
    common.TTServeRule(r"^layers\.attn\.w[qkv]$", in_ndim=1),
    common.TTServeRule(r"^layers\.attn\.wo$", in_ndim=2),
    common.TTServeRule(r"^layers\.mlp\.w_(gate|up|down)$", in_ndim=1),
    common.TTServeRule(r"^layers\.moe\.w_(gate|up|down)$", in_ndim=1,
                       stack=2, experts=1),
]
for _fam in ("dense", "moe", "vlm"):
    common.register_tt_serve_rules(_fam, _TT_RULES)
