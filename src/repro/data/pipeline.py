"""Deterministic synthetic data pipeline, host-sharded.

Every batch is a pure function of (seed, step, shard) — no filesystem, no
state — which gives the framework the two properties the runtime layer
needs at scale:

  * exact resumability: after checkpoint restore at step k, the stream
    continues at batch k+1 bit-identically (no data-loader state to save);
  * elastic re-sharding: when the data-parallel world changes, shards are
    re-assigned by pure index arithmetic.

The token stream is a Zipfian LM-like synthetic source with a Markov
backbone so models actually learn structure (losses decrease — used by the
examples and convergence tests).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    markov_order: int = 1
    frontend: Optional[str] = None      # 'frames' | 'patches'
    frontend_len: int = 0
    d_model: int = 0


class SyntheticLM:
    """Markov chain with Zipf-distributed emissions: H(next|cur) is finite,
    so cross-entropy has a learnable floor below ln(V)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # per-state preferred continuation table (cheap Markov structure)
        self._shift = rng.integers(1, v, size=(min(v, 65536),))

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b_local = cfg.global_batch // num_shards
        seed = (cfg.seed * 1_000_003 + step) * 65_537 + shard
        rng = np.random.default_rng(seed)
        v = cfg.vocab_size
        # zipf-ish ranks clipped to vocab
        base = rng.zipf(cfg.zipf_a, size=(b_local, cfg.seq_len + 1))
        toks = (base - 1) % v
        # Markov mixing: with p=0.5 the next token is a deterministic
        # function of the current one (learnable structure)
        det = self._shift[toks[:, :-1] % len(self._shift)]
        coin = rng.random((b_local, cfg.seq_len)) < 0.5
        nxt = np.where(coin, (toks[:, :-1] + det) % v, toks[:, 1:])
        toks = np.concatenate([toks[:, :1], nxt], axis=1).astype(np.int32)
        out = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
        if cfg.frontend == "frames":
            out["frames"] = rng.standard_normal(
                (b_local, cfg.frontend_len, cfg.d_model)
            ).astype(np.float32)
        elif cfg.frontend == "patches":
            out["prefix_embeds"] = rng.standard_normal(
                (b_local, cfg.frontend_len, cfg.d_model)
            ).astype(np.float32)
        return out

    def iterator(self, start_step: int = 0, shard: int = 0,
                 num_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, shard, num_shards)
            step += 1


def for_model(cfg, shape, seed: int = 1234) -> SyntheticLM:
    """DataConfig derived from a ModelConfig + ShapeConfig."""
    return SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        frontend=cfg.frontend,
        frontend_len=cfg.frontend_len,
        d_model=cfg.d_model,
    ))
