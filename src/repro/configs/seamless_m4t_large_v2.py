"""seamless-m4t-large-v2 — encoder-decoder backbone; frame-embedding stub
frontend [arXiv:2308.11596]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,            # decoder layers
    enc_layers=24,            # encoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    act="relu",
    frontend="frames",        # STUB: input_specs() provides frame embeddings
    frontend_len=1024,        # encoder memory length (precomputed frames)
    rope_theta=10_000.0,
    tie_embeddings=True,
    microbatch=4,
)
