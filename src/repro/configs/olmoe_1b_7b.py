"""olmoe-1b-7b — MoE 64 experts top-8, fine-grained d_ff=1024 [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, num_experts_per_tok=8, d_ff=1024),
    tie_embeddings=False,
    microbatch=4,
)
