"""qwen3-32b — dense, GQA kv=8, qk_norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25_600,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    microbatch=16,
)
