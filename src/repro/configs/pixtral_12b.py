"""pixtral-12b — pixtral-ViT frontend STUB + mistral-nemo-style decoder
backbone [hf:mistralai/Pixtral-12B-2409]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=131_072,
    head_dim=128,
    rope_theta=1_000_000.0,
    frontend="patches",       # STUB: input_specs() provides patch embeddings
    frontend_len=256,         # patches per image prepended to the sequence
    tie_embeddings=False,
    microbatch=8,
)
