"""mamba2-1.3b — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    d_ff=0,                   # no separate MLP: Mamba-2 blocks only
    vocab_size=50_280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    tie_embeddings=True,
    fsdp=False,
    microbatch=8,
    notes="SSD dual form: chunked quadratic intra-chunk + linear inter-chunk "
          "state passing; O(1)-state decode.",
)
