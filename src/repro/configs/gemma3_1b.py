"""gemma3-1b — dense, GQA kv=1, 5:1 local:global, 262k vocab [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262_144,
    head_dim=256,
    qk_norm=True,
    act="gelu",
    window=512,               # sliding window for local layers
    global_every=6,           # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    logit_softcap=None,
    fsdp=False,
    microbatch=4,
    notes="local:global 5:1; long_500k applicable (windowed local layers; "
          "global layers decode linearly in KV with seq-sharded cache).",
)
