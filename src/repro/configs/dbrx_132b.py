"""dbrx-132b — MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10_752,
    vocab_size=100_352,
    moe=MoEConfig(num_experts=16, num_experts_per_tok=4, d_ff=10_752),
    rope_theta=500_000.0,
    tie_embeddings=False,
    microbatch=16,
)
