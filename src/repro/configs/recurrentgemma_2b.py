"""recurrentgemma-2b — RG-LRU + local attention, 1 attn : 2 recurrent [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, HybridConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    act="gelu",
    hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"),
                        lru_width=2560, window=2048),
    tie_embeddings=True,
    microbatch=8,
    notes="26 layers = 8 x (rglru, rglru, attn) + 2 trailing rglru; "
          "local attention window 2048; O(1)-state + window decode.",
)
