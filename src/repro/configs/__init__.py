"""Architecture registry: one module per assigned arch, plus the paper's own
ResNet-32 TTD workload (``resnet32_ttd``)."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    HybridConfig,
    ShapeConfig,
    SHAPES,
    applicable_shapes,
)

ARCH_IDS = [
    "mamba2_1p3b",
    "qwen1p5_0p5b",
    "gemma3_1b",
    "qwen3_32b",
    "qwen3_8b",
    "recurrentgemma_2b",
    "olmoe_1b_7b",
    "dbrx_132b",
    "seamless_m4t_large_v2",
    "pixtral_12b",
]

# canonical assignment names → module ids
NAME_TO_MODULE = {
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "gemma3-1b": "gemma3_1b",
    "qwen3-32b": "qwen3_32b",
    "qwen3-8b": "qwen3_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "dbrx-132b": "dbrx_132b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "pixtral-12b": "pixtral_12b",
}


def get_config(name: str) -> ModelConfig:
    """Look up an architecture config by assignment name or module id."""
    mod_name = NAME_TO_MODULE.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in NAME_TO_MODULE}
