"""Config system: architecture and shape descriptions for the 10-arch zoo.

Every assigned architecture is one ``ModelConfig`` in its own module
(``repro/configs/<id>.py``), selectable by ``--arch <id>`` in the launchers.
``SHAPES`` defines the four assigned input-shape cells; per-arch skips
(long_500k on pure full-attention archs, per DESIGN.md §6) are encoded in
``applicable_shapes``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    d_ff: int                     # per-expert hidden size


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N — SSD state size
    head_dim: int = 64            # P — channels per SSM head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class HybridConfig:
    """recurrentgemma-style mixed blocks."""
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")
    lru_width: Optional[int] = None
    window: int = 2048


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | hybrid | moe | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: Optional[float] = None
    # local/global attention (gemma3): every ``global_every``-th layer global
    window: Optional[int] = None
    global_every: Optional[int] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder
    enc_layers: int = 0
    # modality frontend stub: 'frames' (audio) | 'patches' (vision) | None
    frontend: Optional[str] = None
    frontend_len: int = 0         # stub sequence length of the frontend
    dtype: str = "bfloat16"
    remat: bool = True
    # distribution tuning
    fsdp: bool = True             # shard params/opt-state over the data axis
    microbatch: int = 8           # grad-accumulation microbatches per step
    notes: str = ""
    # ---- beyond-paper perf knobs (EXPERIMENTS.md §Perf; all default OFF so
    # the recorded baseline is the paper-faithful configuration) ----------
    opt_attn_remat: bool = False   # remat each attention q-chunk: the S²
                                   # score stack never becomes a scan residual
    opt_bf16_probs: bool = False   # post-softmax probabilities in bf16 for
                                   # the PV matmul (f32 accumulation)
    opt_bf16_scores: bool = False  # QKᵀ logits stored bf16 (softmax math
                                   # still f32 inside the fused reduction)
    opt_causal_unroll: bool = False  # static causal K-slicing per q-chunk:
                                     # never compute all-masked future blocks
    opt_moe_ep: bool = False         # pin expert-parallel activation layout
                                     # (dispatch all-to-all; no d_ff partial-
                                     # sum all-reduce over the model axis)
    opt_moe_tp: bool = False         # shard expert weights on d_ff (Megatron
                                     # TP): one (cap,D) all-reduce per FFN
                                     # instead of partial-sums of (cap,d_ff)
    opt_moe_a2a: bool = False        # explicit shard_map all-to-all EP
                                     # dispatch (textbook EP; GSPMD cannot
                                     # infer it through the scatter)
    opt_pad_vocab: bool = False      # pad embedding rows to a multiple of
                                     # 256 so vocab SHARDS on the model axis
                                     # (unsharded-vocab logits are fatal at
                                     # 256206×tokens, see §Perf seamless)
    opt_batch_pin: bool = False      # re-constrain the batch dim to the data
                                     # axis inside every block (GSPMD drops
                                     # it across enc-dec scan boundaries)

    @property
    def padded_vocab_size(self) -> int:
        if self.opt_pad_vocab:
            return (self.vocab_size + 255) // 256 * 256
        return self.vocab_size

    def with_opts(self, names) -> "ModelConfig":
        """dataclasses.replace with opt_<name>=True for each name."""
        import dataclasses as _dc
        fields = {f"opt_{n.strip()}": True for n in names if n.strip()}
        known = {f.name for f in _dc.fields(self)}
        unknown = set(fields) - known
        if unknown:
            raise ValueError(f"unknown opt flags: {sorted(unknown)}")
        return _dc.replace(self, **fields)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test-sized variant of the same family (tiny dims)."""
        base = dict(
            num_layers=min(self.num_layers, 2 if self.hybrid is None else 3),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads > 1 else 1,
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.head_dim else None,
            frontend_len=8 if self.frontend else 0,
            enc_layers=2 if self.enc_layers else 0,
            microbatch=1,
        )
        if self.moe:
            base["moe"] = MoEConfig(
                num_experts=8, num_experts_per_tok=2, d_ff=64
            )
        if self.ssm:
            base["ssm"] = SSMConfig(state_dim=16, head_dim=16, chunk=32)
        if self.hybrid:
            base["hybrid"] = HybridConfig(
                pattern=self.hybrid.pattern, lru_width=128, window=32
            )
        if self.window:
            base["window"] = 32
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs whose attention is sub-quadratic (long_500k applicable).
SUBQUADRATIC = {"mamba2-1.3b", "recurrentgemma-2b", "gemma3-1b"}


def applicable_shapes(cfg: ModelConfig):
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.name in SUBQUADRATIC:
        out.append("long_500k")
    return out
