"""FedTTD: the paper's distributed-learning workflow (Fig. 1) on the
multi-pod mesh — local steps per pod, periodic TT-compressed parameter
exchange across the slow pod axis.

Mechanics (DiLoCo-style local-SGD island model):
  * each pod is a synchronous DP×TP island running ``make_train_step``;
  * every ``sync_every`` steps, each pod computes its parameter delta since
    the last sync, TT-compresses it (``core.comm_compress``, error-feedback
    residual kept locally), and exchanges ONLY the TT cores across pods;
  * every pod reconstructs the peers' deltas, averages, and applies.

In the single-process simulator (tests/examples), pods are the leading axis
of a replicated state pytree.  On a real fleet each pod runs its own jit
and the exchange is an ``all_gather`` over the 'pod' mesh axis — the
payload reduction is measured in benchmarks/table_comm.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm_compress import (
    CommCompressionConfig, compress_delta_batched,
)


@dataclasses.dataclass
class FedTTDState:
    anchors: Any                    # params at last sync (per pod)
    residuals: Any                  # error-feedback accumulators (per pod)
    syncs: int = 0
    raw_bytes: float = 0.0          # dense exchange would have cost
    sent_bytes: float = 0.0         # TT payload actually exchanged


def init_state(params_per_pod: List[Any]) -> FedTTDState:
    zeros = [
        jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), p)
        for p in params_per_pod
    ]
    return FedTTDState(anchors=[
        jax.tree.map(lambda p: p.astype(jnp.float32), p)
        for p in params_per_pod
    ], residuals=zeros)


def sync(
    params_per_pod: List[Any],
    state: FedTTDState,
    cfg: CommCompressionConfig,
) -> Tuple[List[Any], FedTTDState]:
    """One cross-pod exchange.  Returns (synced params per pod, new state)."""
    n_pods = len(params_per_pod)
    leaves = [jax.tree.leaves(p) for p in params_per_pod]
    anchor_leaves = [jax.tree.leaves(a) for a in state.anchors]
    resid_leaves = [jax.tree.leaves(r) for r in state.residuals]
    treedef = jax.tree.structure(params_per_pod[0])

    new_params = [[None] * len(leaves[0]) for _ in range(n_pods)]
    new_resid = [[None] * len(leaves[0]) for _ in range(n_pods)]
    raw = sent = 0.0

    for i in range(len(leaves[0])):
        deltas = [
            (leaves[p][i].astype(jnp.float32)
             - anchor_leaves[p][i] + resid_leaves[p][i])
            for p in range(n_pods)
        ]
        payloads = [None] * n_pods
        size = deltas[0].size
        if size >= cfg.min_size:
            # every pod syncs the same leaf shape — a ready-made bucket:
            # ONE vmapped launch compresses all pods' deltas (bit-identical
            # to the per-pod serial loop it replaces)
            tts, resid_stack = compress_delta_batched(
                jnp.stack(deltas), cfg
            )
            all_ranks = np.asarray(tts.ranks)            # (P, N+1)
            for p in range(n_pods):
                # transmit LIVE-rank core slices (ranks are concrete on the
                # host at send time); dense fallback if TT doesn't pay off
                ranks = all_ranks[p]
                live = sum(
                    int(ranks[k]) * n * int(ranks[k + 1])
                    for k, n in enumerate(tts.shape)
                )
                if live < size:
                    payloads[p] = deltas[p] - resid_stack[p]
                    new_resid[p][i] = resid_stack[p]
                    sent += live * 4
                else:
                    payloads[p] = deltas[p]
                    new_resid[p][i] = jnp.zeros_like(deltas[p])
                    sent += size * 4
                raw += size * 4
        else:
            for p in range(n_pods):
                payloads[p] = deltas[p]
                new_resid[p][i] = jnp.zeros_like(deltas[p])
                sent += size * 4
                raw += size * 4
        avg = sum(payloads) / n_pods
        for p in range(n_pods):
            new_params[p][i] = (
                anchor_leaves[p][i] + avg
            ).astype(leaves[p][i].dtype)

    params_out = [jax.tree.unflatten(treedef, np_) for np_ in new_params]
    anchors = [
        jax.tree.map(lambda x: x.astype(jnp.float32), p) for p in params_out
    ]
    resid_out = [jax.tree.unflatten(treedef, r) for r in new_resid]
    return params_out, FedTTDState(
        anchors=anchors,
        residuals=resid_out,
        syncs=state.syncs + 1,
        raw_bytes=state.raw_bytes + raw,
        sent_bytes=state.sent_bytes + sent,
    )
