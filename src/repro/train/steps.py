"""Train / serve step builders: grad accumulation, remat, sharded lowering.

``make_train_step`` returns the canonical fully-synchronous step (DP over
pod×data + TP/EP over model, FSDP per config): microbatch scan accumulates
fp32 gradients, AdamW updates sharded states, XLA inserts the gradient
all-reduces implied by the output shardings.

``make_fedttd_sync`` is the paper-derived alternative for the cross-pod
link: pods run local steps (the train step above, with the pod axis held
out of the batch), and every H steps exchange TT-compressed parameter
deltas (core/comm_compress) — see train/fedttd.py for the driver.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamW, AdamWState, apply_updates


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(
    model,
    optimizer: AdamW,
    microbatch: Optional[int] = None,
    batch_axes=("pod", "data"),
    impl: str = "xla",
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    cfg = model.cfg
    mbs = microbatch or cfg.microbatch

    def loss_for(params, mb):
        loss, metrics = model.loss_fn(params, mb, impl=impl)
        return loss, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        params = state.params

        def split_mb(x):
            b = x.shape[0]
            assert b % mbs == 0, (b, mbs)
            xr = x.reshape(mbs, b // mbs, *x.shape[1:])
            if not batch_axes:          # unsharded (single-device) mode
                return xr
            # keep the per-microbatch shard layout on (pod, data)
            return jax.lax.with_sharding_constraint(
                xr, P(None, batch_axes, *([None] * (x.ndim - 1)))
            )

        batch_r = jax.tree.map(split_mb, batch)
        grad_fn = jax.value_and_grad(loss_for, has_aux=True)

        def mb_step(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads
            )
            return acc, loss

        acc0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        gacc, losses = jax.lax.scan(mb_step, acc0, batch_r)
        grads = jax.tree.map(lambda g: g / mbs, gacc)

        updates, opt = optimizer.update(grads, state.opt, params)
        params = apply_updates(params, updates)
        metrics = {
            "loss": losses.mean(),
            "grad_norm": _norm(grads),
            "lr": optimizer.lr_at(opt.step),
        }
        return TrainState(params=params, opt=opt), metrics

    return train_step


def _norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(tree))
    )


def make_prefill_step(model, impl: str = "xla") -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, impl=impl)
    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode_step


def make_eval_step(model, impl: str = "xla") -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch, impl=impl)
        return metrics
    return eval_step
