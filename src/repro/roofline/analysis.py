"""Roofline extraction from a compiled dry-run artifact.

Conventions (documented per DESIGN.md §7):
  * ``compiled.cost_analysis()`` on a GSPMD-partitioned module reports the
    PER-DEVICE program: flops/bytes are per chip per step.
  * Collective bytes are parsed from the partitioned HLO text: for every
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute we take the RESULT shape (per-device) and apply a
    ring-transfer multiplier:
        all-reduce      2× result        (reduce-scatter + all-gather)
        all-gather      1× result        ((n-1)/n ≈ 1 of the gathered out)
        reduce-scatter  group_size× result ≈ 1× input
        all-to-all      1× result
        collective-permute 1× result
  * Terms (seconds, per step, per chip):
        compute    = flops / PEAK_FLOPS_BF16
        memory     = hbm_bytes / HBM_BW
        collective = ici_bytes / (ICI_LINKS × ICI_BW) + dci_bytes / DCI_BW
    Collectives whose replica group spans more than one pod (group crosses a
    256-device boundary) are charged to the DCI link.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import Counter
from typing import Dict, Optional, Tuple

from repro.launch import mesh as mesh_mod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _pod_size() -> int:
    from repro.roofline import hlo_walk
    return hlo_walk.POD_SIZE


def _group_info(line: str) -> Tuple[int, bool]:
    """(group_size, crosses_pod_boundary) for a collective HLO line.

    Delegates to the exact iota-group materializer in ``hlo_walk``."""
    from repro.roofline import hlo_walk
    m = hlo_walk._GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2)), hlo_walk._iota_crosses(m)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        pod = _pod_size()
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        crosses = (max(ids) // pod) != (min(ids) // pod) if ids else False
        return max(len(ids), 1), crosses
    return 1, False


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by class, plus op counts."""
    out = {"ici_bytes": 0.0, "dci_bytes": 0.0}
    counts: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        nbytes = _shape_bytes(result_type)
        gsize, crosses = _group_info(line)
        if op == "all-reduce":
            moved = 2.0 * nbytes
        elif op == "reduce-scatter":
            moved = float(nbytes) * max(gsize - 1, 1)
        else:
            moved = float(nbytes)
        counts[op] += 1
        key = "dci_bytes" if crosses else "ici_bytes"
        out[key] += moved
    out["op_counts"] = dict(counts)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                   # per device per step
    hbm_bytes: float
    ici_bytes: float
    dci_bytes: float
    op_counts: Dict[str, int]
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    xla_cost_analysis_flops: float = 0.0   # raw (loop bodies counted once)
    xla_cost_analysis_bytes: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops / mesh_mod.PEAK_FLOPS_BF16
        self.memory_s = self.hbm_bytes / mesh_mod.HBM_BW
        self.collective_s = (
            self.ici_bytes / (mesh_mod.ICI_LINKS * mesh_mod.ICI_BW_PER_LINK)
            + self.dci_bytes / mesh_mod.DCI_BW
        )
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        return self

    @property
    def step_time_s(self) -> float:
        """Simple max-of-terms model (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the step the MXU would be busy = roofline fraction."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(compiled, hlo_text: Optional[str] = None) -> Roofline:
    """Primary path: the trip-count-aware HLO walker (hlo_walk.py) —
    ``cost_analysis()`` counts while bodies once, which undercounts every
    scanned program; raw cost_analysis numbers are preserved for reference."""
    from repro.roofline import hlo_walk
    ca = compiled.cost_analysis()
    if isinstance(ca, list):       # older jax returns [dict]
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    w = hlo_walk.walk(text)
    r = Roofline(
        flops=w.flops,
        hbm_bytes=w.hbm_bytes,
        ici_bytes=w.ici_bytes,
        dci_bytes=w.dci_bytes,
        op_counts={k: int(v) for k, v in w.op_counts.items()},
    ).finalize()
    r.xla_cost_analysis_flops = float(ca.get("flops", 0.0))
    r.xla_cost_analysis_bytes = float(ca.get("bytes accessed", 0.0))
    return r


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill forward, 2·N per decode token
    (N = active params excl. embeddings; D = tokens processed)."""
    n_active = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch      # one token per sequence


def active_params(cfg) -> float:
    """Per-token active parameter count (MoE counts top-k experts only)."""
    d = cfg.d_model
    if cfg.family == "ssm":
        di = cfg.ssm.expand * d
        n_layer = d * (2 * di + 2 * cfg.ssm.state_dim
                       + di // cfg.ssm.head_dim) + di * d
        return cfg.num_layers * n_layer
    dh = cfg.resolved_head_dim
    attn_p = d * dh * (cfg.num_heads + 2 * cfg.num_kv_heads) \
        + cfg.num_heads * dh * d
    if cfg.moe:
        ffn = 3 * d * cfg.moe.d_ff * cfg.moe.num_experts_per_tok \
            + d * cfg.moe.num_experts
    else:
        ffn = 3 * d * cfg.d_ff
    layers = cfg.num_layers * (attn_p + ffn)
    if cfg.family == "hybrid":
        r = cfg.hybrid.lru_width or d
        n_tr = cfg.num_layers // 3
        rec_p = 2 * d * r + 2 * r * r + r * d + 3 * d * cfg.d_ff
        att_p = attn_p + 3 * d * cfg.d_ff
        layers = n_tr * (2 * rec_p + att_p) + (cfg.num_layers - 3 * n_tr) * rec_p
    if cfg.family == "encdec":
        layers = layers + cfg.enc_layers * (attn_p + 3 * d * cfg.d_ff) \
            + cfg.num_layers * attn_p       # cross-attention
    return float(layers)
