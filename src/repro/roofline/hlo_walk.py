"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scanned program (layer scans, microbatch accumulation, attention chunking —
i.e. everything this framework lowers) is undercounted by the loop trip
counts.  This walker parses the optimized HLO text and computes:

  * flops        — 2·prod(result)·prod(contracting dims) per ``dot``,
                   scaled by the product of enclosing loop trip counts;
  * hbm_bytes    — a fusion-boundary traffic model (see _instr_bytes):
                   materialized buffers are read/written once per execution;
                   sliced reads charge the slice, reductions charge their
                   full inputs;
  * collectives  — per-op moved bytes (ring-transfer multipliers) × trip
                   counts, split ICI vs cross-pod DCI by replica-group span.

The model is a roofline estimate, not a simulator: its job is to rank terms
and expose deltas under optimization, with each convention documented.
"""

from __future__ import annotations

import dataclasses
import re
import warnings
from collections import Counter, defaultdict
from typing import Dict, Iterator, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# dims accept bounded extents (`f32[<=8,4]`, dynamic-shape HLO prints them)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[((?:<=)?[0-9,<=]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_ARRAY_TYPE_RE = re.compile(r"[a-z0-9]+\[(?:<=)?[0-9,<=]*\](?:\{[^}]*\})?")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(
    r"(calls|body|condition|to_apply|branch_computations)="
    r"(\{[^}]*\}|%[\w.\-]+)"
)
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Devices per pod: collectives whose replica group spans a pod boundary are
# charged to DCI.  Default = v5e-256; the dry-run overrides it from the mesh.
POD_SIZE = 256


def set_pod_size(n: int) -> None:
    global POD_SIZE
    POD_SIZE = max(int(n), 1)


def _dims(dim_str: str) -> List[int]:
    # bounded dims (`<=8`) are charged at their bound — an upper estimate,
    # consistent with the roofline's job of ranking terms
    return ([int(d.replace("<=", "")) for d in dim_str.split(",") if d]
            if dim_str else [])


_warned_dtypes: Set[str] = set()


def _dtype_bytes(dt: str) -> int:
    """Bytes per element, warning ONCE per unknown dtype token instead of
    silently assuming 4 (new XLA dtypes — f4/f8 variants — show up in
    optimized HLO before anyone updates the table)."""
    try:
        return _DTYPE_BYTES[dt]
    except KeyError:
        if dt not in _warned_dtypes:
            _warned_dtypes.add(dt)
            warnings.warn(
                f"hlo_walk: unknown HLO dtype {dt!r}; assuming 4 bytes/elem",
                stacklevel=3,
            )
        return 4


def iter_shapes(type_str: str) -> Iterator[Tuple[str, List[int]]]:
    """(dtype, dims) for every array shape in an HLO type string — flat
    arrays and arbitrarily nested tuples alike.  Shared with the analysis
    layer (``repro.analysis``), which scans optimized HLO for forbidden
    dtypes with the same parser the roofline uses for byte accounting."""
    for dt, dims in _SHAPE_RE.findall(type_str):
        yield dt, _dims(dims)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in iter_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _dtype_bytes(dt)
    return total


def _first_shape(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    return _dims(m.group(2)) if m else []


def _last_shape_bytes(type_str: str) -> int:
    ms = _SHAPE_RE.findall(type_str)
    if not ms:
        return 0
    dt, dims = ms[-1]
    n = 1
    for d in _dims(dims):
        n *= d
    return n * _dtype_bytes(dt)


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: List[str]
    calls: Dict[str, List[str]]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]              # instr name -> result type string
    is_fused: bool = False              # called via fusion `calls=`


def _match_instr(line: str) -> Optional[Tuple[str, str, str, int]]:
    """(name, type_str, opcode, operand_paren_idx) for an instruction line.

    The result type is either an array type or a tuple; tuples can nest
    (``((f32[2]{0}, s32[]), f32[4])``), so the tuple arm scans balanced
    parens instead of trusting a one-level regex.
    """
    head = _INSTR_HEAD_RE.match(line)
    if not head:
        return None
    pos = head.end()
    if pos < len(line) and line[pos] == "(":
        depth = 0
        end = -1
        for i in range(pos, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str = line[pos:end + 1]
        pos = end + 1
    else:
        mt = _ARRAY_TYPE_RE.match(line, pos)
        if not mt:
            return None
        type_str = mt.group(0)
        pos = mt.end()
    mo = _OPCODE_RE.match(line, pos)
    if not mo:
        return None
    return head.group(1), type_str, mo.group(1), mo.end() - 1


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.endswith("{"):
                cur = Computation(name=m.group(1), instrs=[], shapes={})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _match_instr(line)
        if not m:
            continue
        name, type_str, opcode, paren_idx = m
        # operand names: inside the first (...) after opcode
        paren = line[paren_idx:]
        depth = 0
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(paren[:end + 1])
        calls: Dict[str, List[str]] = {}
        for attr, val in _CALL_ATTR_RE.findall(line):
            names = _OPERAND_RE.findall(val)
            calls.setdefault(attr, []).extend(names)
        cur.instrs.append(Instr(name, type_str, opcode, line, operands, calls))
        cur.shapes[name] = type_str
    return comps


def _mark_fused(comps: Dict[str, Computation]):
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                for c in ins.calls.get("calls", []):
                    if c in comps:
                        comps[c].is_fused = True


def _trip_count(comps, cond_name: str) -> int:
    """Max integer constant reachable in the condition computation."""
    seen = set()
    stack = [cond_name]
    best = 1
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        for ins in comps[c].instrs:
            for m in _CONST_INT_RE.finditer(ins.line):
                best = max(best, int(m.group(1)))
            for lst in ins.calls.values():
                stack.extend(lst)
    return best


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """mult[comp] = how many times the computation executes per step."""
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # topological propagation via worklist (HLO call graph is a DAG)
    work = [entry]
    visited_edges = set()
    while work:
        parent = work.pop()
        pm = mult[parent]
        if parent not in comps:
            continue
        for ins in comps[parent].instrs:
            edges: List[Tuple[str, float]] = []
            if ins.opcode == "while":
                trip = _trip_count(comps, ins.calls.get("condition", [""])[0])
                for b in ins.calls.get("body", []):
                    edges.append((b, float(trip)))
                for c in ins.calls.get("condition", []):
                    edges.append((c, float(trip + 1)))
            else:
                for lst in ins.calls.values():
                    for c in lst:
                        edges.append((c, 1.0))
            for child, factor in edges:
                key = (parent, ins.name, child)
                if key in visited_edges:
                    continue
                visited_edges.add(key)
                mult[child] += pm * factor
                work.append(child)
    return dict(mult)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_shape = _first_shape(ins.type_str)
    n_out = 1
    for d in out_shape:
        n_out *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    cdims = _dims(m.group(1)) if m else []
    lhs = ins.operands[0] if ins.operands else None
    lhs_shape = _first_shape(comp.shapes.get(lhs, "")) if lhs else []
    k = 1
    for ci in cdims:
        if ci < len(lhs_shape):
            k *= lhs_shape[ci]
    return 2.0 * n_out * max(k, 1)


_SKIP_MEM = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota", "rng-bit-generator",
}


def _operand_bytes(ins: Instr, comp: Computation) -> List[int]:
    out = []
    for op in ins.operands:
        t = comp.shapes.get(op)
        out.append(_type_bytes(t) if t else 0)
    return out


def _fusion_root_is_dus(ins: Instr, comps: Dict[str, Computation]) -> bool:
    for c in ins.calls.get("calls", []):
        called = comps.get(c)
        if called and called.instrs and \
                called.instrs[-1].opcode == "dynamic-update-slice":
            return True
    return False


def _instr_bytes(ins: Instr, comp: Computation,
                 comps: Optional[Dict[str, Computation]] = None) -> float:
    """HBM traffic model per instruction execution."""
    op = ins.opcode
    if op in _SKIP_MEM or op.startswith("async"):
        return 0.0
    res = _type_bytes(ins.type_str)
    if op in ("dynamic-slice", "gather", "slice"):
        return 2.0 * res
    if op == "dynamic-update-slice":
        opb = _operand_bytes(ins, comp)
        upd = opb[1] if len(opb) > 1 else 0
        return 2.0 * upd + 1024          # window rw + index overhead
    if op == "copy" or op.startswith("copy"):
        return 2.0 * res
    if op == "dot" or op == "convolution":
        opb = _operand_bytes(ins, comp)
        return res + float(sum(opb))
    if op == "fusion":
        kind = "kInput" if "kind=kInput" in ins.line else (
            "kOutput" if "kind=kOutput" in ins.line else "kLoop")
        opb = _operand_bytes(ins, comp)
        if comps is not None and _fusion_root_is_dus(ins, comps):
            # dynamic-update-slice-rooted fusion (scan-stack/KV-cache write):
            # in-place semantics touch only the updated window, not the
            # whole aliased buffer.  The window is the largest operand
            # strictly smaller than the result buffer.
            win = max((b for b in opb if b < res), default=res)
            return 2.0 * win + 1024
        if kind == "kInput":             # reduction-rooted: reads full inputs
            return res + float(sum(opb))
        # loop/output fusions stream result-sized tiles; sliced operands
        # inside the fusion read at most result-size from each operand
        return res + float(sum(min(b, res) for b in opb))
    if op in ("reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
        opb = _operand_bytes(ins, comp)
        return res + float(sum(opb))
    if any(op.startswith(c) for c in COLLECTIVE_OPS):
        return 0.0                       # charged to the collective term
    # default elementwise-ish op
    opb = _operand_bytes(ins, comp)
    return res + float(sum(min(b, res) if b else 0 for b in opb))


def _collective_moved(ins: Instr) -> Tuple[float, int, bool, str]:
    """(moved_bytes, group_size, crosses_pod, opbase) for a collective."""
    opbase = next(c for c in COLLECTIVE_OPS if ins.opcode.startswith(c))
    if ins.opcode.endswith("-done"):
        return 0.0, 1, False, opbase
    if ins.opcode.endswith("-start"):
        nbytes = _last_shape_bytes(ins.type_str)
    else:
        nbytes = _type_bytes(ins.type_str)
    gsize, crosses = 1, False
    m = _GROUPS_IOTA_RE.search(ins.line)
    if m:
        gsize = int(m.group(2))
        crosses = _iota_crosses(m)
    else:
        m2 = _GROUPS_LIST_RE.search(ins.line)
        if m2:
            ids = [int(x) for x in m2.group(1).split(",") if x.strip()]
            gsize = max(len(ids), 1)
            crosses = bool(ids) and (max(ids) // POD_SIZE) != (min(ids) // POD_SIZE)
    if opbase == "all-reduce":
        moved = 2.0 * nbytes
    elif opbase == "reduce-scatter":
        moved = float(nbytes) * max(gsize - 1, 1)
    else:
        moved = float(nbytes)
    if opbase == "collective-permute":
        crosses = crosses or "source_target_pairs" in ins.line and \
            _permute_crosses(ins.line)
    return moved, gsize, crosses, opbase


def _iota_crosses(m) -> bool:
    """Exact check for iota replica groups [G,S]<=[dims](T(perm))?: build the
    device-id array, reshape/transpose per the iota spec, and test whether any
    group holds devices from more than one pod."""
    import numpy as np
    num_groups, gsize = int(m.group(1)), int(m.group(2))
    dims = [int(d) for d in m.group(3).split(",") if d]
    n = int(np.prod(dims))
    if num_groups * gsize != n or n > 1 << 16:
        return gsize > POD_SIZE          # malformed/huge: conservative
    ids = np.arange(n).reshape(dims)
    if m.group(4):
        perm = [int(d) for d in m.group(4).split(",") if d]
        ids = ids.transpose(perm)
    groups = ids.reshape(num_groups, gsize)
    pods = groups // POD_SIZE
    return bool((pods.max(axis=1) != pods.min(axis=1)).any())


def _permute_crosses(line: str) -> bool:
    m = re.search(r"source_target_pairs=\{([^}]*)\}", line)
    if not m:
        return False
    for pair in m.group(1).split("},{"):
        ids = [int(x) for x in re.findall(r"\d+", pair)]
        if len(ids) >= 2 and (ids[0] // POD_SIZE) != (ids[1] // POD_SIZE):
            return True
    return False


@dataclasses.dataclass
class WalkResult:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    dci_bytes: float = 0.0
    op_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    trip_counted_loops: int = 0


def walk(hlo_text: str) -> WalkResult:
    comps = parse_hlo(hlo_text)
    _mark_fused(comps)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    if m:
        entry = m.group(1)
    else:  # fall back: last computation
        entry = list(comps)[-1] if comps else ""
    mult = _multipliers(comps, entry)

    res = WalkResult()
    counts: Counter = Counter()
    for comp in comps.values():
        cm = mult.get(comp.name, 0.0)
        if cm <= 0:
            continue
        for ins in comp.instrs:
            if ins.opcode == "while":
                res.trip_counted_loops += 1
            if ins.opcode == "dot" or ins.opcode == "convolution":
                res.flops += cm * _dot_flops(ins, comp)
            if not comp.is_fused:
                res.hbm_bytes += cm * _instr_bytes(ins, comp, comps)
            if any(ins.opcode.startswith(c) for c in COLLECTIVE_OPS):
                moved, gsize, crosses, opbase = _collective_moved(ins)
                if moved > 0:
                    counts[opbase] += cm
                    if crosses:
                        res.dci_bytes += cm * moved
                    else:
                        res.ici_bytes += cm * moved
    res.op_counts = {k: float(v) for k, v in counts.items()}
    return res
