"""Sharded checkpointing with manifest + reshard-on-restore.

Layout (one directory per step):
    <dir>/step_000100/
        manifest.json            — tree structure, shapes, dtypes, step,
                                   mesh shape, data-stream cursor
        shard_<k>.npz            — flat arrays owned by host k (single-host
                                   runs write shard_0 with everything)
        _COMMITTED               — atomic commit marker (written last)

Fault-tolerance contract (runtime/fault_tolerance.py):
  * restore() ignores uncommitted (crashed mid-write) checkpoints;
  * arrays are restorable onto a DIFFERENT mesh: values are saved unsharded
    (gathered) per leaf, and re-sharded by the caller's shardings on load —
    elastic restarts change the mesh without touching the checkpoint;
  * save is atomic-per-step and keeps the newest ``keep`` steps;
  * integrity: every array gets a sha256 in the manifest at save time and
    is verified on load (``verify=False`` opts out) — a truncated or
    bit-flipped shard raises ``CheckpointCorrupt`` naming the bad leaf
    instead of silently serving garbage weights;
  * stale ``*.tmp`` directories from crashed saves are detected and
    cleaned when a ``CheckpointManager`` opens the directory.
"""

from __future__ import annotations

import glob
import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A committed checkpoint failed integrity verification (truncated
    archive, bit-flipped array, missing leaf).  The message names the
    offending file/leaf; the operator restores from an older step or
    re-exports the payload."""


def _sha256(arr: np.ndarray) -> str:
    """Content hash of one array as stored: dtype + shape + raw bytes, so
    a reinterpreted (right bytes, wrong dtype) leaf also fails."""
    a = np.ascontiguousarray(arr)
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def _load_npz(path: str):
    """np.load with corruption mapped to CheckpointCorrupt (a truncated or
    bit-flipped zip raises BadZipFile/zlib.error/ValueError deep inside
    numpy — surface them as one typed error)."""
    try:
        return np.load(path)
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointCorrupt(
            f"{path}: unreadable archive ({type(e).__name__}: {e})") from e


def _get_array(data, key: str, path: str) -> np.ndarray:
    try:
        return data[key]
    except KeyError:
        raise CheckpointCorrupt(f"{path}: missing array {key!r}") from None
    except Exception as e:   # per-member CRC/zlib failure on decompress
        raise CheckpointCorrupt(
            f"{path}: array {key!r} unreadable "
            f"({type(e).__name__}: {e})") from e


def _verify_sums(data, sums: Dict[str, str], path: str) -> None:
    for key in sorted(sums):
        got = _sha256(_get_array(data, key, path))
        if got != sums[key]:
            raise CheckpointCorrupt(
                f"{path}: checksum mismatch for leaf {key!r} "
                f"(expected {sums[key][:12]}…, got {got[:12]}…) — shard is "
                f"truncated or bit-flipped")


def clean_stale_tmp(directory: str) -> List[str]:
    """Remove ``*.tmp`` directories left by saves that crashed before
    their atomic rename.  Returns the paths removed.  Safe to call on an
    open checkpoint dir as long as no save is in flight."""
    removed = []
    for tmp in glob.glob(os.path.join(directory, "*.tmp")):
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
            removed.append(tmp)
    return removed


def _flatten_with_names(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path) or "_root"
        out.append((name, leaf))
    return out, treedef


def _key_str(k) -> str:
    if hasattr(k, "name"):
        return str(k.name)
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ---------------------------------------------------------------------------
# TT payload checkpointing (compressed wire format, Fig. 1 edge→cloud)
# ---------------------------------------------------------------------------
#
# A TTCompressor payload is a params-shaped pytree of CompressedParam
# leaves; saving it instead of the dense state keeps the checkpoint at the
# compressed size AND lets the serving side restore straight into TT-native
# mode (``models.common.tt_native_params``) without ever holding the dense
# weights.  Layout: one directory with
#     tt_manifest.json  — per-leaf kind/shape/dtype/ranks/eps/crop metadata
#     tt_payload.npz    — raw leaves + TT cores (cores keep their dtype)
#     _COMMITTED        — atomic commit marker

def save_tt_payload(directory: str, payload, extra: Optional[Dict] = None,
                    family: Optional[str] = None,
                    quant: Optional[str] = None,
                    quant_calib: str = "absmax") -> str:
    """Serialize a TTCompressor payload (CompressedParam pytree).

    family: the model family (``cfg.family``) the payload was compressed
    from, recorded in the manifest so a TT-native restore can select the
    right serving-rule set (and refuse a payload from the wrong arch).

    quant: integer storage format (``"int8"``) or None.  When set, TT cores
    are written symmetrically quantized (one scale per core, stored beside
    it as ``<key>__core<k>__scale``) — the on-disk payload shrinks ~4x on
    the cores.  ``load_tt_payload`` dequantizes back to the wide core dtype;
    the restored values sit exactly on the quantization grid, so a serving-
    side requantization (``tt_native_params(quant=...)`` with absmax
    calibration) reproduces the integer values and scales bit-identically —
    the round-trip is lossless relative to the quantized form."""
    from repro.core.compression import CompressedParam
    from repro.core import tt_linear as _ttl

    qdt = None if quant is None else _ttl.quant_dtype(quant)

    def is_cp(x):
        return isinstance(x, CompressedParam)

    flat, _ = jax.tree_util.tree_flatten_with_path(payload, is_leaf=is_cp)
    arrays: Dict[str, np.ndarray] = {}
    leaves = []
    for path, c in flat:
        name = "/".join(_key_str(k) for k in path) or "_root"
        if not is_cp(c):
            raise TypeError(f"{name}: not a CompressedParam leaf: {type(c)}")
        key = name.replace("/", "__")
        meta = {
            "name": name,
            "kind": c.kind,
            "orig_shape": list(c.orig_shape),
            "orig_dtype": str(jax.numpy.dtype(c.orig_dtype)),
            "crop_dims": list(c.crop_dims) if c.crop_dims else None,
        }
        if c.kind == "tt":
            meta["tt"] = {
                "shape": list(c.tt.shape),
                "ranks": [int(r) for r in c.tt.ranks],
                "eps": float(c.tt.eps),
                "core_dtypes": [str(g.dtype) for g in c.tt.cores],
            }
            if qdt is not None:
                meta["tt"]["quant"] = {"dtype": quant, "calib": quant_calib}
                for k, g in enumerate(c.tt.cores):
                    q, s = _ttl.quantize_array(
                        jax.numpy.asarray(g), dtype=qdt, calib=quant_calib
                    )
                    arrays[f"{key}__core{k}"] = np.asarray(jax.device_get(q))
                    arrays[f"{key}__core{k}__scale"] = np.asarray(
                        jax.device_get(s), np.float32
                    )
            else:
                for k, g in enumerate(c.tt.cores):
                    arrays[f"{key}__core{k}"] = np.asarray(
                        jax.device_get(g), np.float32
                    )
        else:
            # raw leaves round-trip through f32 (np lacks bf16/fp8 writers)
            arrays[f"{key}__raw"] = np.asarray(
                jax.device_get(jax.numpy.asarray(c.raw).astype(
                    jax.numpy.float32))
            )
        leaves.append(meta)

    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "tt_payload.npz"), **arrays)
    manifest = {"time": time.time(), "leaves": leaves, "extra": extra or {},
                "family": family, "quant": quant,
                "sha256": {k: _sha256(v) for k, v in arrays.items()}}
    with open(os.path.join(tmp, "tt_manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    # crash-safe swap: the previous committed payload is parked at .old (not
    # deleted) until the new one is in place; load_tt_payload falls back to
    # .old, so every crash window leaves at least one loadable payload
    old = directory + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(directory):
        os.rename(directory, old)
    os.rename(tmp, directory)
    if os.path.exists(old):
        shutil.rmtree(old)
    return directory


def load_tt_payload(directory: str, like, verify: bool = True
                    ) -> Tuple[Any, Dict]:
    """Restore a TT payload into the tree structure of ``like`` (the params
    pytree the payload was compressed from, or any same-structure tree).

    ``verify=True`` (default) checks every array against the sha256 the
    manifest recorded at save time and raises ``CheckpointCorrupt`` naming
    the bad leaf; payloads written before checksums existed load without
    verification either way."""
    import jax.numpy as jnp

    from repro.core.compression import CompressedParam
    from repro.core.tt import TTTensor

    if not os.path.exists(os.path.join(directory, "_COMMITTED")):
        old = directory + ".old"        # interrupted save_tt_payload swap
        if os.path.exists(os.path.join(old, "_COMMITTED")):
            directory = old
        else:
            raise FileNotFoundError(f"no committed TT payload in {directory}")
    with open(os.path.join(directory, "tt_manifest.json")) as f:
        manifest = json.load(f)
    npz_path = os.path.join(directory, "tt_payload.npz")
    data = _load_npz(npz_path)
    if verify and manifest.get("sha256"):
        _verify_sums(data, manifest["sha256"], npz_path)

    named, treedef = _flatten_with_names(like)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    missing = set(by_name) ^ {n for n, _ in named}
    if missing:   # leaves resolve by name, so ordering differences are fine
        raise ValueError(f"payload/tree structure mismatch: {sorted(missing)}")

    leaves = []
    for name, _ in named:
        m = by_name[name]
        key = name.replace("/", "__")
        dtype = jnp.dtype(m["orig_dtype"])
        crop = tuple(m["crop_dims"]) if m.get("crop_dims") else None
        if m["kind"] == "tt":
            quant = m["tt"].get("quant")
            cores = []
            for k, cd in enumerate(m["tt"]["core_dtypes"]):
                arr = data[f"{key}__core{k}"]
                if quant is not None:
                    # dequantize to the wide core dtype: restored values sit
                    # exactly on the integer grid, so requantizing at serve
                    # time (absmax) is bit-identical to what was saved
                    arr = (np.asarray(arr, np.float32)
                           * np.asarray(data[f"{key}__core{k}__scale"],
                                        np.float32))
                cores.append(jnp.asarray(arr, jnp.dtype(cd)))
            tt = TTTensor(
                cores=cores, shape=tuple(m["tt"]["shape"]),
                ranks=tuple(m["tt"]["ranks"]), eps=m["tt"]["eps"],
            )
            leaves.append(CompressedParam(
                "tt", tt, None, tuple(m["orig_shape"]), dtype,
                crop_dims=crop,
            ))
        else:
            raw = jnp.asarray(data[f"{key}__raw"]).astype(dtype)
            leaves.append(CompressedParam(
                "raw", None, raw, tuple(m["orig_shape"]), dtype,
                crop_dims=crop,
            ))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        # a save that crashed before its atomic rename leaves step_*.tmp
        # behind; nothing references it, so reclaim the space on open
        self.cleaned_tmp = clean_stale_tmp(directory)

    # ---------------- save ----------------

    def save(self, step: int, state, extra: Optional[Dict] = None) -> str:
        """Snapshot state (device→host copy happens synchronously; disk write
        is async unless async_save=False — the paper's clock-gating analogue:
        I/O overlaps the next step's compute)."""
        self.wait()
        named, _ = _flatten_with_names(state)

        def to_host(v):
            a = np.asarray(jax.device_get(v))
            if a.dtype.kind not in "fiub":      # ml_dtypes (bf16/fp8) -> fp32
                a = np.asarray(jax.numpy.asarray(a).astype(np.float32))
            return a

        host = [(n, to_host(v)) for n, v in named]
        path = self._step_dir(step)

        def write():
            tmp = path + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{n.replace("/", "__"): v for n, v in host})
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": [
                    {"name": n, "shape": list(v.shape), "dtype": str(v.dtype),
                     "sha256": _sha256(v)}
                    for n, v in host
                ],
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()
        return path

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ---------------- restore ----------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.dir, d, "_COMMITTED")
            ):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, state_like, step: Optional[int] = None,
                shardings=None, verify: bool = True) -> Tuple[Any, Dict]:
        """Restore into the structure of ``state_like``; apply ``shardings``
        (a matching pytree of NamedSharding) if given — this is where
        elastic mesh changes are absorbed.

        ``verify=True`` (default) re-hashes every shard array against the
        manifest's sha256 and raises ``CheckpointCorrupt`` naming the bad
        leaf; checkpoints from before checksums load unverified."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self._step_dir(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        shard_path = os.path.join(path, "shard_0.npz")
        data = _load_npz(shard_path)
        if verify:
            sums = {m["name"].replace("/", "__"): m["sha256"]
                    for m in manifest["leaves"] if "sha256" in m}
            _verify_sums(data, sums, shard_path)
        named, treedef = _flatten_with_names(state_like)
        leaves = []
        sh_flat = None
        if shardings is not None:
            sh_named, _ = _flatten_with_names(shardings)
            sh_flat = [s for _, s in sh_named]
        for i, (n, like) in enumerate(named):
            arr = _get_array(data, n.replace("/", "__"), shard_path)
            # cast via jnp (numpy lacks cast kernels for bf16/fp8 ml_dtypes)
            if hasattr(like, "dtype") and arr.dtype != like.dtype:
                arr = np.asarray(jax.numpy.asarray(arr).astype(like.dtype))
            if sh_flat is not None:
                leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest

    # ---------------- misc ----------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:06d}")

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
