"""WY trailing-matrix update kernel — the TTD-Engine's REQUEST-GEMM stage.

Computes  A_out = A - V · (Tᵀ · (Vᵀ · A))   (compact-WY block reflector)

as two MXU GEMM passes, with the Householder panel (V, T) resident in VMEM
across both — the TPU transliteration of TT-Edge's two design points:
"reflector application = two GEMMs on the existing GEMM array" and
"Householder vectors stay in the SPM".

Pass 1 (``_vta_kernel``):   Y = Vᵀ A          grid (N/bn, M/bm), accumulate
                                               over the M-tile axis
Pass 2 (``_update_kernel``): A_out = A - V W   with W = Tᵀ Y precomputed in
                                               pass 1.5 (a b×b · b×bn GEMM
                                               folded into pass 2's prologue)

Tile shapes are MXU-aligned (multiples of 128 where the problem allows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vta_kernel(v_ref, a_ref, y_ref):
    """Y[b, bn] += V[bm, b]^T @ A[bm, bn]; M-tile axis accumulates."""
    m_idx = pl.program_id(1)

    @pl.when(m_idx == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        v_ref[...].T, a_ref[...], preferred_element_type=jnp.float32
    )


def _update_kernel(a_ref, v_ref, w_ref, out_ref):
    """A_out[bm, bn] = A[bm, bn] - V[bm, b] @ W[b, bn]."""
    acc = a_ref[...].astype(jnp.float32) - jnp.dot(
        v_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "interpret")
)
def wy_update(
    a: jax.Array,
    v: jax.Array,
    t: jax.Array,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """A - V Tᵀ Vᵀ A with (M, N) A, (M, b) V, (b, b) T.  M, N must be
    divisible by (bm, bn) — ops.py pads."""
    m, n = a.shape
    b = v.shape[1]
    assert m % bm == 0 and n % bn == 0, (a.shape, bm, bn)

    # ---- pass 1: Y = V^T A  (grid: N tiles outer, M tiles inner/accum) ----
    y = pl.pallas_call(
        _vta_kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bm, b), lambda j, i: (i, 0)),       # V tile
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),      # A tile
        ],
        out_specs=pl.BlockSpec((b, bn), lambda j, i: (0, j)),  # Y tile
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(v, a)

    # ---- pass 1.5: W = T^T Y (small b×b GEMM; XLA fuses it) ----
    w = t.T.astype(jnp.float32) @ y

    # ---- pass 2: A_out = A - V W ----
    out = pl.pallas_call(
        _update_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),      # A tile
            pl.BlockSpec((bm, b), lambda i, j: (i, 0)),       # V tile (VMEM-resident)
            pl.BlockSpec((b, bn), lambda i, j: (0, j)),       # W tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, v, w.astype(a.dtype))
    return out
