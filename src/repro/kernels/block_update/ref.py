"""Pure-jnp oracle for the WY trailing update."""

import jax
import jax.numpy as jnp


def wy_update_ref(a: jax.Array, v: jax.Array, t: jax.Array) -> jax.Array:
    """A - V Tᵀ Vᵀ A, computed in fp32, cast back to A's dtype."""
    a32 = a.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    t32 = t.astype(jnp.float32)
    y = v32.T @ a32
    return (a32 - v32 @ (t32.T @ y)).astype(a.dtype)
