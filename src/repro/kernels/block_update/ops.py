"""jit'd public wrapper for the WY trailing-update kernel (pads + dispatches)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.block_update.kernel import wy_update as _kernel
from repro.kernels.block_update.ref import wy_update_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_wy_update(
    a: jax.Array, v: jax.Array, t: jax.Array, interpret: bool | None = None
) -> jax.Array:
    """A ← (I − V T Vᵀ)ᵀ A = A − V Tᵀ Vᵀ A with automatic padding/tiling.

    a: (M, N) trailing matrix; v: (M, b) panel reflectors; t: (b, b) WY factor.
    """
    if interpret is None:
        interpret = common.use_interpret()
    m, n = a.shape
    bm = common.pick_tile(m)
    bn = common.pick_tile(n)
    mp = common.round_up(m, bm)
    np_ = common.round_up(n, bn)
    a_p = common.pad_to(a, mp, np_)
    v_p = common.pad_to(v, mp, v.shape[1])
    out = _kernel(a_p, v_p, t, bm=bm, bn=bn, interpret=interpret)
    return out[:m, :n]


__all__ = ["block_wy_update", "wy_update_ref"]
