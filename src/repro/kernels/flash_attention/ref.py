"""Dense-softmax oracle for the flash attention kernel."""

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    sm_scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """q, k, v: (BH, S, D).  Materializes the full score matrix."""
    bh, s_len, d = q.shape
    if sm_scale is None:
        sm_scale = d ** -0.5
    scores = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * sm_scale
    qp = jnp.arange(s_len)[:, None]
    kp = jnp.arange(s_len)[None, :]
    mask = jnp.ones((s_len, s_len), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    scores = jnp.where(mask[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
