"""Flash attention (prefill) Pallas kernel — online-softmax tiled attention.

Not part of the paper's contribution, but the perf-critical compute layer of
the architecture zoo this framework must serve (DESIGN.md §3).  Grid is
(batch·heads, Q blocks); K/V for the head stream through VMEM while the
(bq, d) query block and the online-softmax state stay resident.

Supports causal masking and an optional sliding window (gemma3 /
recurrentgemma local-attention layers).  For dry-run lowering on the 512-way
mesh the models use the pure-XLA chunked path (``models/attention.py``);
this kernel is the TPU execution target and is validated in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, sm_scale,
                  causal, window, seq_len):
    qi = pl.program_id(1)
    q = q_ref[0, :, :].astype(jnp.float32) * sm_scale      # (bq, d)
    d = q.shape[-1]
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    nkv = seq_len // bk

    def body(kv_i, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, pl.dslice(kv_i * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kv_i * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                        # (bq, bk) MXU
        k_pos = kv_i * bk + jax.lax.iota(jnp.int32, bk)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v                 # MXU
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)

    if causal:
        # skip fully-masked KV blocks beyond the diagonal
        hi = jnp.minimum((qi + 1) * bq, seq_len)
        nkv_live = pl.cdiv(hi, bk)
    else:
        nkv_live = nkv
    acc, m, l = jax.lax.fori_loop(0, nkv_live, body, (acc0, m0, l0))
    l = jnp.where(l == 0, 1.0, l)
    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "causal", "window", "sm_scale", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    sm_scale: float | None = None,
    causal: bool = True,
    window: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (BH, S, D) — batch·heads flattened.  Returns (BH, S, D)."""
    bh, s, d = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    if sm_scale is None:
        sm_scale = d ** -0.5

    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, sm_scale=sm_scale,
        causal=causal, window=window, seq_len=s,
    )
    return pl.pallas_call(
        kern,
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
