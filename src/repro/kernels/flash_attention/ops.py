"""jit'd wrapper: shape plumbing for (B, H, S, D) attention + GQA expansion."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention.kernel import flash_attention as _kernel
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "interpret")
)
def mha_flash(
    q: jax.Array,            # (B, S, Hq, D)
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,            # (B, S, Hkv, D)
    causal: bool = True,
    window: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """GQA-aware flash attention: repeats KV heads to match Q heads, flattens
    (B, H) into the kernel grid, picks hardware-aligned block sizes."""
    if interpret is None:
        interpret = common.use_interpret()
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    bq = min(128, s)
    bk = min(128, s)
    out = _kernel(
        qf, kf, vf, causal=causal, window=window, bq=bq, bk=bk,
        interpret=interpret,
    )
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


__all__ = ["mha_flash", "attention_ref"]
