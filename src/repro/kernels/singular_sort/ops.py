"""jit'd wrapper: kernel sort + basis permutation (paper Alg. 1 lines 18-25)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.singular_sort.kernel import bitonic_sort_desc as _kernel
from repro.kernels.singular_sort.kernel import (
    bitonic_sort_desc_batched as _kernel_batched,
)
from repro.kernels.singular_sort.ref import sort_desc_ref, sorting_basis_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_singular_values(s: jax.Array, interpret: bool | None = None):
    if interpret is None:
        interpret = common.use_interpret()
    return _kernel(s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_singular_values_batched(
    s: jax.Array, interpret: bool | None = None
):
    """One launch sorting every row of a (B, n) σ stack descending."""
    if interpret is None:
        interpret = common.use_interpret()
    return _kernel_batched(s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sorting_basis(
    u: jax.Array, s: jax.Array, vt: jax.Array, interpret: bool | None = None
):
    """Sorted (U_s, Σ_s, V_sᵀ) using the kernel's index vector for the
    basis permutation — exactly the paper's SORTING-module contract."""
    s_sorted, ind = sort_singular_values(s, interpret=interpret)
    return u[:, ind], s_sorted, vt[ind, :]


__all__ = [
    "sort_singular_values", "sort_singular_values_batched", "sorting_basis",
    "sort_desc_ref", "sorting_basis_ref",
]
