"""Bitonic singular-value sort kernel — the SORTING module on TPU.

The paper's SORTING module bubble-sorts σ in the SPM while recording an
index vector that later permutes U's columns and Vᵀ's rows.  A serial bubble
sort is the bit-serial-hardware idiom; on a vector machine the same
(sorted σ, index vector) contract is produced by a **bitonic sorting
network** — compare-exchanges expressed as reshape/select over the
VMEM-resident vector, log²(n) fully-vectorized stages, no data-dependent
control flow.

The kernel sorts DESCENDING and emits the paper's index vector; the basis
permutation (Alg. 1 line 22) is a gather applied in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.4e38


def _compare_exchange(s, idx, j, k, n):
    """One bitonic stage: partner = i XOR j, descending iff (i AND k) == 0."""
    s2 = s.reshape(n // (2 * j), 2, j)
    i2 = idx.reshape(n // (2 * j), 2, j)
    lo_s, hi_s = s2[:, 0, :], s2[:, 1, :]
    lo_i, hi_i = i2[:, 0, :], i2[:, 1, :]
    # block b covers indices [b*2j, (b+1)*2j); bit log2(k) of i is constant
    # within the block because 2j <= k at every (k, j) stage of the network
    base = jnp.arange(n // (2 * j)) * (2 * j)
    desc = (base & k) == 0                       # descending regions
    swap = jnp.where(desc[:, None], lo_s < hi_s, lo_s > hi_s)
    new_lo_s = jnp.where(swap, hi_s, lo_s)
    new_hi_s = jnp.where(swap, lo_s, hi_s)
    new_lo_i = jnp.where(swap, hi_i, lo_i)
    new_hi_i = jnp.where(swap, lo_i, hi_i)
    s = jnp.stack([new_lo_s, new_hi_s], axis=1).reshape(n)
    idx = jnp.stack([new_lo_i, new_hi_i], axis=1).reshape(n)
    return s, idx


def _sort_kernel(s_ref, out_s_ref, out_idx_ref, *, n):
    s = s_ref[0, :].astype(jnp.float32)
    idx = jax.lax.iota(jnp.int32, n)
    k = 2
    while k <= n:                                 # static: log n stages
        j = k // 2
        while j >= 1:
            s, idx = _compare_exchange(s, idx, j, k, n)
            j //= 2
        k *= 2
    out_s_ref[0, :] = s
    out_idx_ref[0, :] = idx


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_desc_batched(s: jax.Array, interpret: bool = False):
    """Sort each row of a (B, n) stack descending in ONE kernel launch.

    The batch axis is the leading grid dimension — each grid program runs
    the full bitonic network on its own VMEM-resident row.  Returns
    (sorted (B,n), index_vectors (B,n)); row k equals
    ``bitonic_sort_desc(s[k])``.
    """
    bsz, n = s.shape
    n_pad = 1 << (n - 1).bit_length()
    s_p = jnp.full((bsz, n_pad), NEG_INF, jnp.float32)
    s_p = s_p.at[:, :n].set(s.astype(jnp.float32))

    kern = functools.partial(_sort_kernel, n=n_pad)
    out_s, out_idx = pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, n_pad), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((1, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, n_pad), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((bsz, n_pad), jnp.int32),
        ),
        interpret=interpret,
    )(s_p)
    return out_s[:, :n].astype(s.dtype), out_idx[:, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_desc(s: jax.Array, interpret: bool = False):
    """Sort (n,) descending; returns (sorted, index_vector).  Pads to a power
    of two with -inf sentinels (dropped before returning)."""
    n = s.shape[0]
    n_pad = 1 << (n - 1).bit_length()
    s_p = jnp.full((n_pad,), NEG_INF, jnp.float32)
    s_p = s_p.at[:n].set(s.astype(jnp.float32))

    kern = functools.partial(_sort_kernel, n=n_pad)
    out_s, out_idx = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n_pad), lambda i: (0, 0))],
        out_specs=(
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
        ),
        interpret=interpret,
    )(s_p[None, :])
    return out_s[0, :n].astype(s.dtype), out_idx[0, :n]
