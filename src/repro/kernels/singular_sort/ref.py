"""argsort oracle for the bitonic sort kernel (paper Sorting_Basis)."""

import jax
import jax.numpy as jnp


def sort_desc_ref(s: jax.Array):
    """Descending sort + index vector (the paper's Bubble_Sort contract)."""
    idx = jnp.argsort(-s.astype(jnp.float32)).astype(jnp.int32)
    return s[idx], idx


def sorting_basis_ref(u: jax.Array, s: jax.Array, vt: jax.Array):
    s_sorted, ind = sort_desc_ref(s)
    return u[:, ind], s_sorted, vt[ind, :]
