"""Dispatch wrapper for the fused TT-contraction kernels.

``tt_contract`` takes the lead-absorbed chain (see ``ref.py`` for the
representation) and picks the execution path:

  * depth 2 (split 1)      → fused ``tt_contract_2``
  * depth 3 (split 1 or 2) → fused ``tt_contract_3``
  * anything else, or chains whose operands would blow the VMEM budget
                           → the jnp einsum chain (``tt_contract_ref``),
                             still unmaterialized, just unfused

All paths return float32 — callers (``core/tt_linear.tt_apply``) cast back
to the activation dtype after the chain, matching how the dense path's
einsums accumulate.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.tt_contract import kernel as _kernel
from repro.kernels.tt_contract.ref import (
    tt_contract_batched_ref, tt_contract_ref, tt_dense_ref, tt_dequant_chain,
)


def resolve_tile_cap(b: int, tile: Optional[int] = None):
    """Token-dim tile cap candidates for a (B, N_in) activation extent,
    best first.

    Priority: explicit ``tile`` argument > TT_CONTRACT_TILE env var >
    adaptive default.  An explicit cap (arg or env) is a single candidate —
    tuning intent is never second-guessed; if its footprint fails the VMEM
    gate the chain falls back unfused, not to a different tile.  The
    adaptive default grows past the historical 512 cap when the flattened
    batch×token extent divides cleanly (fewer grid steps per launch), but
    keeps the smaller caps as fallbacks so growing the cap can only ever
    ADD fused coverage: a shape whose big-tile footprint flunks the gate
    retries at the tile it would have used before."""
    if tile is not None:
        return (_validated_cap(tile, "tile="),)
    env = os.environ.get("TT_CONTRACT_TILE")
    if env:
        return (_validated_cap(env, "the TT_CONTRACT_TILE env var"),)
    caps = [cap for cap in (2048, 1024) if b >= cap and b % cap == 0]
    return (*caps, _kernel.DEFAULT_TILE_CAP)


def _validated_cap(value, source: str) -> int:
    """An explicit tile cap must be a positive integer — reject junk with a
    message naming where it came from (a bad TT_CONTRACT_TILE used to
    surface as an opaque int() ValueError deep in the dispatch)."""
    try:
        cap = int(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{source} must be a positive integer tile cap, got {value!r}"
        ) from None
    if cap <= 0:
        raise ValueError(
            f"{source} must be a positive integer tile cap, got {value!r}"
        )
    return cap


def _core_tile_bytes(g) -> int:
    """VMEM bytes a resident core tile occupies.  Integer cores pass into
    the kernels in their storage dtype (the fused dequant widens them only
    as compute values, never as a resident tile), so they cost itemsize
    bytes; float cores are pre-cast to f32 at the entry point, so their
    resident cost is 4 bytes regardless of the caller-side dtype."""
    if jnp.issubdtype(jnp.dtype(g.dtype), jnp.integer):
        return int(g.size) * jnp.dtype(g.dtype).itemsize
    return int(g.size) * 4


def _fits_vmem(x2, cores, n_out: int, split: int,
               tile_cap: int = _kernel.DEFAULT_TILE_CAP) -> bool:
    """Bytes of one grid step at the tile _grid_1d will actually pick:
    activation tile in + out, cores fully resident, PLUS the largest
    intermediate the fused body materializes — the depth-3 expand path's
    ``(bb, n_mid·r2)`` tile can dwarf both activation tiles and used to be
    unaccounted, letting oversized chains onto the fused path.

    Activation tiles and intermediates are always f32 (4 bytes); cores are
    accounted at their per-element itemsize — an int8 core tile is a
    quarter the f32 footprint, and assuming uniform 4-byte elements here
    would wrongly bounce near-budget quantized chains off the fused path
    (and, symmetrically, would under-gate if wide intermediates were ever
    accounted at a narrow itemsize)."""
    bb = _kernel._grid_1d(x2.shape[0], tile_cap)
    n_in = x2.shape[1]
    if len(cores) == 2:
        interm = bb * cores[0].shape[1]                   # t = x·g0 (bb, r1)
    else:
        r1 = cores[0].shape[1]
        n_mid, r2 = cores[1].shape[1], cores[1].shape[2]
        # producer and consumer tiles are live at the same time inside the
        # fused body, so they SUM (a max() here would repeat the original
        # under-count one level down)
        if split == 1:
            interm = bb * (r1 + n_mid * r2)               # t1 + (bb,n_mid·r2)
        else:
            # transposed x copy (bb·n_mid, n1) + partial (bb·n_mid, r1)
            # + contracted (bb, r2)
            interm = bb * (n_in + n_mid * r1 + r2)
    ops_bytes = (4 * (bb * (n_in + n_out) + interm)
                 + sum(_core_tile_bytes(g) for g in cores))
    return ops_bytes < common.VMEM_BUDGET // 2


def _combined_scale(scales) -> Optional[jax.Array]:
    """Product of the non-``None`` per-core dequant scales, or ``None`` when
    the chain is unquantized.  The TT chain is linear in every core, so the
    per-core symmetric scales commute out to one output multiply."""
    if scales is None:
        return None
    combined = None
    for s in scales:
        if s is None:
            continue
        s = jnp.asarray(s, jnp.float32)
        combined = s if combined is None else combined * s
    return combined


def tt_contract(
    x2: jax.Array,                  # (B, N_in)
    cores: Sequence[jax.Array],     # [g0 (n1,r1), g_k (r,n,s)..., last s==1]
    split: int,
    interpret: bool | None = None,
    tile: Optional[int] = None,     # token-dim tile cap override
    scales: Optional[Sequence[Optional[jax.Array]]] = None,
) -> jax.Array:                     # (B, N_out) float32
    """Contract activations straight through TT cores (no dense weight).

    ``scales`` (aligned with ``cores``, ``None`` entries = already-wide
    cores) selects the dequant-fused kernels: integer cores ride into the
    kernel in storage dtype and the scale product folds into the output
    tile.  The unfused fallback dequantizes via the same linearity —
    ``tt_contract_ref(x, cores) * ∏scales``."""
    if interpret is None:
        interpret = common.use_interpret()
    depth = len(cores)
    x2 = x2.astype(jnp.float32)
    combined = _combined_scale(scales)
    n_out = 1
    for g in cores[split:]:
        n_out *= g.shape[1]
    # first candidate cap whose grid-step footprint clears the VMEM gate
    cap = None
    for c in resolve_tile_cap(x2.shape[0], tile):
        if _fits_vmem(x2, cores, n_out, split, c):
            cap = c
            break

    if depth == 2 and split == 1 and cap is not None:
        g0, g1 = cores
        g1m = g1[:, :, 0] if g1.ndim == 3 else g1
        if combined is not None:
            return _kernel.tt_contract_2q(
                x2, g0, g1m, combined, interpret=interpret, tile_cap=cap,
            )
        return _kernel.tt_contract_2(
            x2, g0, g1m, interpret=interpret, tile_cap=cap,
        )

    if depth == 3 and split in (1, 2) and cap is not None:
        g0, g1, g2 = cores
        g2m = g2[:, :, 0] if g2.ndim == 3 else g2          # (r2, n3)
        if split == 1:
            r1, n2, r2 = g1.shape
            g1f = g1.reshape(r1, n2 * r2)
            if combined is not None:
                return _kernel.tt_contract_3q(
                    x2, g0, g1f, g2m, combined, split=1, n_mid=n2,
                    n_out=n2 * g2m.shape[1], interpret=interpret,
                    tile_cap=cap,
                )
            return _kernel.tt_contract_3(
                x2, g0, g1f, g2m, split=1, n_mid=n2,
                n_out=n2 * g2m.shape[1], interpret=interpret, tile_cap=cap,
            )
        r1, n2, r2 = g1.shape
        g1p = g1.transpose(1, 0, 2).reshape(n2 * r1, r2)   # (n2·r1, r2)
        if combined is not None:
            return _kernel.tt_contract_3q(
                x2, g0, g1p, g2m, combined, split=2, n_mid=n2,
                n_out=g2m.shape[1], interpret=interpret, tile_cap=cap,
            )
        return _kernel.tt_contract_3(
            x2, g0, g1p, g2m, split=2, n_mid=n2,
            n_out=g2m.shape[1], interpret=interpret, tile_cap=cap,
        )

    y = tt_contract_ref(x2, cores, split)
    return y if combined is None else y * combined


def tt_contract_batched(
    x3: jax.Array,                  # (E, B, N_in)
    g0b: jax.Array,                 # (E, n1, r1) per-expert lead-absorbed
    cores: Sequence[jax.Array],     # shared tail [(r,n,s), ...], last s==1
    split: int,
    interpret: bool | None = None,
    tile: Optional[int] = None,
    scales: Optional[Sequence[Optional[jax.Array]]] = None,
) -> jax.Array:                     # (E, B, N_out) float32
    """Expert-batched TT chain: the whole bank in one launch.

    Experts share every tail core — only the lead-absorbed first core
    differs — so vmapping the fused dispatch over the expert axis gives the
    Pallas kernels an extra grid dimension (one launch, E×(B/bb) grid steps)
    while oversized chains still take the per-expert einsum fallback.  The
    VMEM gate applies per grid step, which is exactly the per-expert tile.

    ``scales`` aligns with the shared tail ``cores`` (the per-expert lead is
    handed in wide, its per-row scales folded by the caller), so the scale
    product is expert-invariant and closes over the vmap unbatched."""
    rest = list(cores)
    chain_scales = None if scales is None else [None] + list(scales)
    return jax.vmap(
        lambda x2, g0: tt_contract(x2, [g0] + rest, split,
                                   interpret=interpret, tile=tile,
                                   scales=chain_scales)
    )(x3, g0b)


__all__ = [
    "resolve_tile_cap", "tt_contract", "tt_contract_batched",
    "tt_contract_batched_ref", "tt_contract_ref", "tt_dense_ref",
    "tt_dequant_chain",
]
