"""Fused TT-contraction Pallas kernels — the TT-native serving hot path.

A TT-compressed layer weight applied to activations is a short chain of
small matmuls (eq. (1)/(2) contractions with the activation folded in).
Unfused, every intermediate ``(B, ·)`` tensor round-trips through HBM and
each hop is a separate dispatch; fused, the whole chain runs out of one
VMEM residency per activation tile — decode-sized batches are latency-bound
on exactly that.

Two bodies cover the shapes the model zoo produces (``tensorize_dims``
keeps ≥3-D stacked layer weights mode-per-axis, so after the layer index is
absorbed a (L,D,F) MLP weight is a 2-core chain and (L,D,H,K)/(L,H,K,D)
attention weights are 3-core chains):

  * ``tt_contract_2`` — y = (x @ g0) @ g1
  * ``tt_contract_3`` — 3-core chain, input/output structure selected by
    ``split`` (1 = one input core, 2 = two input cores)

Cores sit whole in VMEM (they are the *compressed* payload — KBs); the
grid tiles the token dimension.  Deeper chains fall back to the jnp oracle
(``ref.py``) in ``ops.py``.

Quantized variants (``tt_contract_2q``/``tt_contract_3q``) take the tail
cores in their integer STORAGE dtype — int8 rides HBM→VMEM at one byte per
element, the cast to f32 happens on the VMEM tile inside the kernel body,
and the symmetric dequant scales (one scalar per core; the chain is linear
in each core, so they commute out) fold into a single multiply on the
output tile.  The wide form of a stored core never exists outside VMEM —
that is the whole point: decode streams int8, the MXU computes f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _tt2_kernel(x_ref, g0_ref, g1_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    t = _dot(x, g0_ref[...])                              # (bb, r1)   MXU
    o_ref[...] = _dot(t, g1_ref[...])                     # (bb, n2)   MXU


def _tt3_kernel(x_ref, g0_ref, g1_ref, g2_ref, o_ref, *, split, n_mid, bb):
    """3-core chain on one (bb, N_in) activation tile.

    split=1: x (bb,n1) · g0 (n1,r1) · g1 (r1,n2·r2) · g2 (r2,n3)
             — expand path: t (bb,n2·r2) reshapes to (bb·n2, r2) rows.
    split=2: x (bb,n1·n2) · g0 (n1,r1) · g1 (n2·r1,r2) · g2 (r2,n3)
             — contract path: x transposes so the major input mode hits
             the MXU as the contracting dim; g1 is pre-permuted to
             (n2, r1, r2) row-major by ops.py.
    """
    x = x_ref[...].astype(jnp.float32)
    g0, g1, g2 = g0_ref[...], g1_ref[...], g2_ref[...]
    if split == 1:
        t = _dot(x, g0)                                   # (bb, r1)
        t = _dot(t, g1)                                   # (bb, n2*r2)
        r2 = g2.shape[0]
        t = t.reshape(bb * n_mid, r2)
        y = _dot(t, g2)                                   # (bb*n2, n3)
        o_ref[...] = y.reshape(bb, n_mid * g2.shape[1])
    else:
        n1 = g0.shape[0]
        x3 = x.reshape(bb, n1, n_mid)
        x3 = x3.transpose(0, 2, 1).reshape(bb * n_mid, n1)
        t = _dot(x3, g0)                                  # (bb*n2, r1)
        t = t.reshape(bb, n_mid * g0.shape[1])
        t = _dot(t, g1)                                   # (bb, r2)
        o_ref[...] = _dot(t, g2)                          # (bb, n3)


def _tt2q_kernel(x_ref, g0_ref, g1_ref, s_ref, o_ref):
    """Dequant-fused 2-core body: g1 arrives in its storage dtype (int8) and
    widens on the VMEM tile; the symmetric scale rides in as a (1, 1) f32
    operand and folds into the output tile."""
    x = x_ref[...].astype(jnp.float32)
    t = _dot(x, g0_ref[...].astype(jnp.float32))          # (bb, r1)
    y = _dot(t, g1_ref[...].astype(jnp.float32))          # (bb, n2)
    o_ref[...] = y * s_ref[0, 0]


def _tt3q_kernel(x_ref, g0_ref, g1_ref, g2_ref, s_ref, o_ref,
                 *, split, n_mid, bb):
    """Dequant-fused 3-core body: same dataflow as ``_tt3_kernel`` but the
    tail cores (g1, g2) stay in storage dtype until the in-VMEM cast, and
    the combined per-core scale product lands as one multiply at the end —
    valid because the chain is linear in each core."""
    x = x_ref[...].astype(jnp.float32)
    g0 = g0_ref[...].astype(jnp.float32)
    g1 = g1_ref[...].astype(jnp.float32)
    g2 = g2_ref[...].astype(jnp.float32)
    s = s_ref[0, 0]
    if split == 1:
        t = _dot(x, g0)                                   # (bb, r1)
        t = _dot(t, g1)                                   # (bb, n2*r2)
        r2 = g2.shape[0]
        t = t.reshape(bb * n_mid, r2)
        y = _dot(t, g2)                                   # (bb*n2, n3)
        o_ref[...] = y.reshape(bb, n_mid * g2.shape[1]) * s
    else:
        n1 = g0.shape[0]
        x3 = x.reshape(bb, n1, n_mid)
        x3 = x3.transpose(0, 2, 1).reshape(bb * n_mid, n1)
        t = _dot(x3, g0)                                  # (bb*n2, r1)
        t = t.reshape(bb, n_mid * g0.shape[1])
        t = _dot(t, g1)                                   # (bb, r2)
        o_ref[...] = _dot(t, g2) * s                      # (bb, n3)


DEFAULT_TILE_CAP = 512


def _grid_1d(b: int, cap: int = DEFAULT_TILE_CAP):
    """Token-dim tile: first of (cap, cap/2, cap/4) that divides b, else the
    whole batch in one block.  ops.py gates kernel eligibility on the VMEM
    footprint of the tile THIS returns, so an indivisible huge batch (whole-b
    block) falls back to the unfused chain instead of blowing VMEM.

    ``cap`` is the tunable upper bound (ops.py resolves it from the
    TT_CONTRACT_TILE env var / call argument, growing it when the token
    extent allows) — bigger tiles amortize grid overhead per launch, the
    VMEM gate keeps them honest."""
    for t in (cap, cap // 2, cap // 4):
        if b > t and b % t == 0:
            return t
    return b


@functools.partial(jax.jit, static_argnames=("interpret", "tile_cap"))
def tt_contract_2(x, g0, g1, interpret: bool = False,
                  tile_cap: int = DEFAULT_TILE_CAP):
    """(B, n1) · (n1, r1) · (r1, n2) → (B, n2), one launch."""
    b, n1 = x.shape
    n2 = g1.shape[1]
    bb = _grid_1d(b, tile_cap)
    return pl.pallas_call(
        _tt2_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n1), lambda i: (i, 0)),
            pl.BlockSpec(g0.shape, lambda i: (0, 0)),
            pl.BlockSpec(g1.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n2), jnp.float32),
        interpret=interpret,
    )(x, g0.astype(jnp.float32), g1.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("split", "n_mid", "n_out", "interpret", "tile_cap"),
)
def tt_contract_3(x, g0, g1, g2, *, split: int, n_mid: int, n_out: int,
                  interpret: bool = False,
                  tile_cap: int = DEFAULT_TILE_CAP):
    """Fused 3-core chain; ``g1`` comes pre-flattened 2D from ops.py."""
    b, n_in = x.shape
    bb = _grid_1d(b, tile_cap)
    kern = functools.partial(_tt3_kernel, split=split, n_mid=n_mid, bb=bb)
    return pl.pallas_call(
        kern,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n_in), lambda i: (i, 0)),
            pl.BlockSpec(g0.shape, lambda i: (0, 0)),
            pl.BlockSpec(g1.shape, lambda i: (0, 0)),
            pl.BlockSpec(g2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), jnp.float32),
        interpret=interpret,
    )(
        x,
        g0.astype(jnp.float32),
        g1.astype(jnp.float32),
        g2.astype(jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("interpret", "tile_cap"))
def tt_contract_2q(x, g0, g1, scale, interpret: bool = False,
                   tile_cap: int = DEFAULT_TILE_CAP):
    """Quantized 2-core chain: g1 passes through in storage dtype (int8) —
    one byte per element over HBM→VMEM — and ``scale`` (its symmetric
    dequant scale) folds into the output tile.  g0 is the lead-absorbed
    first core, already wide with its scale folded host-side."""
    b, n1 = x.shape
    n2 = g1.shape[1]
    bb = _grid_1d(b, tile_cap)
    return pl.pallas_call(
        _tt2q_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n1), lambda i: (i, 0)),
            pl.BlockSpec(g0.shape, lambda i: (0, 0)),
            pl.BlockSpec(g1.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n2), jnp.float32),
        interpret=interpret,
    )(x, g0.astype(jnp.float32), g1,
      jnp.asarray(scale, jnp.float32).reshape(1, 1))


@functools.partial(
    jax.jit,
    static_argnames=("split", "n_mid", "n_out", "interpret", "tile_cap"),
)
def tt_contract_3q(x, g0, g1, g2, scale, *, split: int, n_mid: int,
                   n_out: int, interpret: bool = False,
                   tile_cap: int = DEFAULT_TILE_CAP):
    """Quantized 3-core chain: tail cores (g1, g2) pass through in storage
    dtype, ``scale`` is the product of their dequant scales."""
    b, n_in = x.shape
    bb = _grid_1d(b, tile_cap)
    kern = functools.partial(_tt3q_kernel, split=split, n_mid=n_mid, bb=bb)
    return pl.pallas_call(
        kern,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, n_in), lambda i: (i, 0)),
            pl.BlockSpec(g0.shape, lambda i: (0, 0)),
            pl.BlockSpec(g1.shape, lambda i: (0, 0)),
            pl.BlockSpec(g2.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), jnp.float32),
        interpret=interpret,
    )(x, g0.astype(jnp.float32), g1, g2,
      jnp.asarray(scale, jnp.float32).reshape(1, 1))
