"""Oracle for the fused TT-contraction kernel: per-core einsum chain.

Operates on the *lead-absorbed* chain representation a ``TTLinear`` hands
down (``core/tt_linear.py``): ``cores[0]`` is 2D ``(n_1, r_1)`` (boundary
rank and any layer-stack modes already contracted away), every later core is
3D ``(r_{k-1}, n_k, r_k)`` and the final core has ``r_N == 1``.  The first
``split`` cores are *input* cores (their mode dims are contracted against
``x``); the rest are *output* cores (their mode dims build the result).

The contraction order matches ``tt_reconstruct`` exactly — left-to-right,
one mode at a time — so fusing it with the activation never changes the
value, only when the work happens (per token instead of one-shot
materialization of the full ``(N_in, N_out)`` matrix).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def tt_contract_ref(
    x2: jax.Array,                  # (B, N_in)
    cores: Sequence[jax.Array],     # [g0 (n1,r1), g_k (r,n,s) ..., last s==1]
    split: int,
) -> jax.Array:                     # (B, N_out) float32
    """y = x · W where W is the TT chain — pure jnp, any depth."""
    assert 1 <= split <= len(cores), (split, len(cores))
    b = x2.shape[0]
    g0 = cores[0]
    assert g0.ndim == 2, "cores[0] must be lead-absorbed (n1, r1)"
    t = x2.astype(jnp.float32).reshape(b, g0.shape[0], -1)
    t = jnp.einsum("bnm,ns->bms", t, g0.astype(jnp.float32))
    for g in cores[1:split]:
        r = g.shape[0]
        t = t.reshape(b, g.shape[1], -1, r)
        t = jnp.einsum("bnmr,rns->bms", t, g.astype(jnp.float32))
    # all input modes consumed: t is (B, 1, r_split)
    t = t.reshape(b, 1, -1)
    for g in cores[split:]:
        t = jnp.einsum("bmr,rns->bmns", t, g.astype(jnp.float32))
        t = t.reshape(b, -1, g.shape[2])
    return t.reshape(b, -1)


def tt_contract_batched_ref(
    x3: jax.Array,                  # (E, B, N_in)
    g0b: jax.Array,                 # (E, n1, r1) per-expert lead-absorbed
    cores: Sequence[jax.Array],     # shared tail [(r,n,s), ...], last s==1
    split: int,
) -> jax.Array:                     # (E, B, N_out) float32
    """Expert-batched chain oracle: y[e] = x[e] · W[e], where the experts
    differ only in their lead-absorbed first core and share every later
    core — written as one einsum chain with a leading expert axis (the
    batched analogue of ``tt_contract_ref``, same left-to-right order)."""
    assert 1 <= split <= 1 + len(cores), (split, len(cores))
    e, b, _ = x3.shape
    assert g0b.ndim == 3 and g0b.shape[0] == e, g0b.shape
    t = x3.astype(jnp.float32).reshape(e, b, g0b.shape[1], -1)
    t = jnp.einsum("ebnm,ens->ebms", t, g0b.astype(jnp.float32))
    for g in cores[: split - 1]:
        r = g.shape[0]
        t = t.reshape(e, b, g.shape[1], -1, r)
        t = jnp.einsum("ebnmr,rns->ebms", t, g.astype(jnp.float32))
    t = t.reshape(e, b, 1, -1)
    for g in cores[split - 1:]:
        t = jnp.einsum("ebmr,rns->ebmns", t, g.astype(jnp.float32))
        t = t.reshape(e, b, -1, g.shape[2])
    return t.reshape(e, b, -1)


def tt_dequant_chain(
    cores: Sequence[jax.Array],
    scales: Sequence[jax.Array | None],
) -> list[jax.Array]:
    """Explicitly dequantize a chain: each core widened to f32 and multiplied
    by its symmetric scale (``None`` = core is already wide — e.g. the
    lead-absorbed first core whose scale was folded host-side).  This is the
    unfused oracle the scale-folded kernels must match at f32 tolerance: the
    chain is linear in every core, so scaling cores individually and scaling
    the output once by the product are the same map."""
    assert len(cores) == len(scales), (len(cores), len(scales))
    out = []
    for g, s in zip(cores, scales):
        g = jnp.asarray(g, jnp.float32)
        if s is not None:
            g = g * jnp.asarray(s, jnp.float32)
        out.append(g)
    return out


def tt_dense_ref(cores: Sequence[jax.Array], split: int) -> jax.Array:
    """Materialize the chain into the dense (N_in, N_out) matrix —
    the reconstruct-then-matmul baseline the fused path must match."""
    acc = jnp.asarray(cores[0], jnp.float32)        # (n1, r1)
    n_in = cores[0].shape[0]
    for k, g in enumerate(cores[1:], start=1):
        r = g.shape[0]
        acc = acc.reshape(-1, r) @ jnp.asarray(g, jnp.float32).reshape(r, -1)
        if k < split:
            n_in *= g.shape[1]
    return acc.reshape(n_in, -1)
