"""Shared kernel plumbing: interpret-mode autodetection and tiling helpers.

All kernels in this package target TPU (pl.pallas_call with explicit
BlockSpec VMEM tiling, MXU-aligned tile shapes).  On non-TPU backends —
including this CPU container — the jit'd wrappers in each ``ops.py`` pass
``interpret=True`` so the kernel body executes exactly as written and can be
validated against the ``ref.py`` oracle.
"""

from __future__ import annotations

import jax
import numpy as np

# MXU native tile; VPU lane width.  All kernel tile shapes are multiples.
MXU_DIM = 128
VPU_LANES = 128
# v5e VMEM budget per core we design against (bytes).
VMEM_BUDGET = 96 * 1024 * 1024


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_tile(dim: int, target: int = MXU_DIM, cap: int = 512) -> int:
    """Largest hardware-aligned tile <= cap that divides the (padded) dim."""
    if dim <= target:
        return round_up(max(dim, 1), 8)
    t = target
    while t * 2 <= cap and dim % (t * 2) == 0:
        t *= 2
    return t


def pad_to(x, rows: int, cols: int):
    """Zero-pad a 2D array up to (rows, cols)."""
    import jax.numpy as jnp

    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))
