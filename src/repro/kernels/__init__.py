"""Pallas TPU kernels (validated in interpret mode on CPU).

Paper-derived (the TTD-Engine datapaths):
  householder    — HBD-ACC panel factorization (HOUSE/VEC-DIV/REQUEST-GEMM)
  block_update   — compact-WY trailing update (two MXU GEMMs, V,T in VMEM)
  singular_sort  — SORTING module (bitonic network + index vector)
  frob_truncate  — TRUNCATION module (reverse-‖·‖F scan vs δ)

Architecture-zoo hot spot:
  flash_attention — online-softmax prefill attention (causal/windowed/GQA)
"""

from repro.kernels.block_update.ops import block_wy_update, wy_update_ref
from repro.kernels.householder.ops import (
    panel_factor,
    panel_factor_ref,
    qr_blocked,
)
from repro.kernels.flash_attention.ops import mha_flash, attention_ref
from repro.kernels.singular_sort.ops import (
    sort_singular_values,
    sorting_basis as kernel_sorting_basis,
)
from repro.kernels.frob_truncate.ops import delta_truncate, frob_truncate_ref
