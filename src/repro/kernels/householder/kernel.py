"""Householder panel-factorization kernel — the HBD-ACC datapath on TPU.

One grid program factors a full (M, b) column panel **entirely in VMEM**:
for each column j it runs the paper's four HBD-ACC stages —

  PREPARE      : select the active column (address calculation ≡ BlockSpec)
  HOUSE        : norm + pivot  q = -sign(x₁)‖x‖,  v₁ = x₁ + sign(x₁)‖x‖
  VEC DIVISION : v ← v / v₁   (LAPACK normalization; β folded into τ)
  REQUEST GEMM : panel update  A ← A − τ v (vᵀ A)   as two in-VMEM GEMMs

— with the Householder vectors accumulating in a VMEM-resident buffer, never
leaving the chip until the panel is done.  That buffer is the TPU analogue
of TT-Edge's "Householder vectors retained in the SPM".

The trailing matrix (everything right of the panel) is updated separately by
``kernels/block_update`` in compact-WY form — the "reuse the GEMM
accelerator" half of the design.

Outputs: V (M, b) normalized reflectors (unit diagonal, zero above),
         taus (1, b), and R (b, b) — the panel's triangular factor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _panel_body(acc0):
    """The HBD-ACC column loop on an (M, b) panel held in VMEM.

    Returns (vs, taus, r_head): normalized reflectors, their taus, and the
    b×b triangular head of the reduced panel.  Shared by the single-panel
    and the batch-grid kernels — the batched variant simply instantiates one
    grid program per panel.
    """
    m, b = acc0.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)[:, 0]

    def col_step(j, carry):
        acc, vs, taus = carry
        mask = rows >= j
        x = jnp.where(mask, acc[:, j], 0.0)
        # ---- HOUSE ----
        norm = jnp.sqrt(jnp.sum(x * x))
        x1 = jnp.sum(jnp.where(rows == j, x, 0.0))
        s = jnp.where(x1 >= 0, 1.0, -1.0)
        pivot = -s * norm
        v1 = x1 + s * norm
        safe = jnp.abs(v1) > 0
        # ---- VEC DIVISION ----
        v = jnp.where(mask, x / jnp.where(safe, v1, 1.0), 0.0)
        v = jnp.where(rows == j, jnp.where(safe, 1.0, 0.0), v)
        tau = jnp.where(safe, s * v1 / jnp.where(norm == 0, 1.0, norm), 0.0)
        # ---- REQUEST GEMM (panel-internal; two GEMMs) ----
        w = v @ acc                                     # (b,)  GEMM #1
        acc = acc - tau * v[:, None] * w[None, :]        # (M,b) GEMM #2 (rank-1)
        # store pivot on the diagonal, retain v below it
        acc = jnp.where(
            (rows == j)[:, None] & (jax.lax.iota(jnp.int32, b) == j)[None, :],
            pivot,
            acc,
        )
        vs = jnp.where((jax.lax.iota(jnp.int32, b) == j)[None, :], v[:, None], vs)
        taus = jnp.where(jax.lax.iota(jnp.int32, b) == j, tau, taus)
        return acc, vs, taus

    vs0 = jnp.zeros((m, b), jnp.float32)
    taus0 = jnp.zeros((b,), jnp.float32)
    acc, vs, taus = jax.lax.fori_loop(0, b, col_step, (acc0, vs0, taus0))

    # R: upper-triangular b×b head of the reduced panel
    cols = jax.lax.iota(jnp.int32, b)
    head = acc[:b, :]
    r_head = jnp.where(cols[:, None] <= cols[None, :], head, 0.0)
    return vs, taus, r_head


def _panel_kernel(a_ref, v_ref, tau_ref, r_ref):
    vs, taus, r_head = _panel_body(a_ref[...].astype(jnp.float32))
    v_ref[...] = vs
    tau_ref[...] = taus[None, :]
    r_ref[...] = r_head


def _panel_kernel_batched(a_ref, v_ref, tau_ref, r_ref):
    # one grid program per batch member; blocks carry a leading length-1
    # batch dim selected by the grid index
    vs, taus, r_head = _panel_body(a_ref[0].astype(jnp.float32))
    v_ref[0] = vs
    tau_ref[0] = taus[None, :]
    r_ref[0] = r_head


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_factor(a_panel: jax.Array, interpret: bool = False):
    """Factor an (M, b) panel: returns (V (M,b), taus (b,), R (b,b))."""
    m, b = a_panel.shape
    v, tau, r = pl.pallas_call(
        _panel_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((m, b), lambda i: (0, 0))],
        out_specs=(
            pl.BlockSpec((m, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, b), jnp.float32),
            jax.ShapeDtypeStruct((1, b), jnp.float32),
            jax.ShapeDtypeStruct((b, b), jnp.float32),
        ),
        interpret=interpret,
    )(a_panel.astype(jnp.float32))
    return v, tau[0], r


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_factor_batched(a_panels: jax.Array, interpret: bool = False):
    """Factor a (B, M, b) stack of panels — the batch axis is the leading
    grid dimension, so all B HBD-ACC programs issue from ONE kernel launch.

    Returns (V (B,M,b), taus (B,b), R (B,b,b)); member k equals
    ``panel_factor(a_panels[k])``.
    """
    bsz, m, b = a_panels.shape
    v, tau, r = pl.pallas_call(
        _panel_kernel_batched,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, m, b), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((1, m, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, m, b), jnp.float32),
            jax.ShapeDtypeStruct((bsz, 1, b), jnp.float32),
            jax.ShapeDtypeStruct((bsz, b, b), jnp.float32),
        ),
        interpret=interpret,
    )(a_panels.astype(jnp.float32))
    return v, tau[:, 0, :], r
