"""jit'd wrappers: panel factorization + the full blocked QR built from the
two TTD-Engine kernels (panel HBD-ACC + WY block_update)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.householder.kernel import panel_factor as _panel_kernel
from repro.kernels.householder.kernel import (
    panel_factor_batched as _panel_kernel_batched,
)
from repro.kernels.householder.ref import panel_factor_ref
from repro.kernels.block_update.ops import block_wy_update


def panel_factor(a_panel: jax.Array, interpret: bool | None = None):
    if interpret is None:
        interpret = common.use_interpret()
    return _panel_kernel(a_panel, interpret=interpret)


def panel_factor_batched(a_panels: jax.Array, interpret: bool | None = None):
    """One launch factoring a (B, M, b) panel stack (batch = grid dim 0)."""
    if interpret is None:
        interpret = common.use_interpret()
    return _panel_kernel_batched(a_panels, interpret=interpret)


def build_t(vs: jax.Array, taus: jax.Array) -> jax.Array:
    """Compact-WY T (forward, columnwise): H_1…H_b = I − V T Vᵀ."""
    b = taus.shape[0]
    vtv = vs.T @ vs

    def step(j, t):
        col = -taus[j] * (t @ (vtv[:, j] * (jnp.arange(b) < j)))
        col = jnp.where(jnp.arange(b) == j, taus[j], col)
        col = jnp.where(jnp.arange(b) <= j, col, 0.0)
        return t.at[:, j].set(col)

    return jax.lax.fori_loop(0, b, step, jnp.zeros((b, b), vs.dtype))


@functools.partial(jax.jit, static_argnames=("panel", "interpret"))
def qr_blocked(
    a: jax.Array, panel: int = 128, interpret: bool | None = None
):
    """Blocked Householder QR A = QR using the Pallas TTD-Engine kernels.

    Returns (Q thin (M,N), R (N,N)).  Pads N to a multiple of ``panel``.
    This is the compute path the paper's Table-III HBD row maps onto:
    panel factorization (HBD-ACC) + WY trailing updates (GEMM reuse).
    """
    if interpret is None:
        interpret = common.use_interpret()
    m, n = a.shape
    np_ = common.round_up(n, panel)
    if np_ != n:
        q, r = qr_blocked(
            jnp.pad(a, ((0, 0), (0, np_ - n))), panel=panel,
            interpret=interpret,
        )
        return q[:, :n], r[:n, :n]

    nblocks = n // panel
    a = a.astype(jnp.float32)
    rows = jnp.arange(m)
    all_vs = []
    all_ts = []
    for k in range(nblocks):
        c0 = k * panel
        # Present the kernel with the active sub-view A[c0:, c0:c0+panel]
        # starting at row 0 (the paper's address-calculator semantics):
        # roll the panel up by c0 and zero the wrapped-around R rows.
        pan = jnp.roll(a[:, c0:c0 + panel], -c0, axis=0)
        pan = jnp.where(rows[:, None] < m - c0, pan, 0.0)
        v_r, taus, r_head = panel_factor(pan, interpret=interpret)
        t = build_t(v_r, taus)
        # roll V back into global row coordinates (zeros wrap to the top)
        v = jnp.roll(v_r, c0, axis=0)
        v = jnp.where(rows[:, None] >= c0, v, 0.0)
        # write the panel's R head into rows c0:c0+panel; zero below pivot
        a = jax.lax.dynamic_update_slice(a, r_head, (c0, c0))
        colsel = (jnp.arange(n) >= c0) & (jnp.arange(n) < c0 + panel)
        below = rows[:, None] >= c0 + panel
        a = jnp.where(colsel[None, :] & below, 0.0, a)
        if k + 1 < nblocks:
            trail = a[:, (k + 1) * panel:]
            trail = block_wy_update(trail, v, t, interpret=interpret)
            a = a.at[:, (k + 1) * panel:].set(trail)
        all_vs.append(v)
        all_ts.append(t)

    r = jnp.triu(a[:n, :n])
    # form thin Q by backward application of the block reflectors to I
    q = jnp.eye(m, n, dtype=jnp.float32)
    for k in reversed(range(nblocks)):
        v, t = all_vs[k], all_ts[k]
        q = q - v @ (t @ (v.T @ q))
    return q, r


__all__ = [
    "panel_factor", "panel_factor_batched", "panel_factor_ref", "build_t",
    "qr_blocked",
]
