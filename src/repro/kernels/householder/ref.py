"""Pure-jnp oracle for the Householder panel factorization."""

import jax
import jax.numpy as jnp


def panel_factor_ref(a_panel: jax.Array):
    """Unblocked Householder QR of an (M, b) panel.

    Returns (V, taus, R) matching kernels/householder/kernel.py:
    V (M, b) unit-lower reflectors, taus (b,), R (b, b) upper-triangular,
    such that (I - tau_b v_b v_bᵀ)···(I - tau_1 v_1 v_1ᵀ) A = [R; 0].
    """
    a = a_panel.astype(jnp.float32)
    m, b = a.shape
    rows = jnp.arange(m)
    vs = jnp.zeros((m, b), jnp.float32)
    taus = jnp.zeros((b,), jnp.float32)

    def step(j, carry):
        acc, vs, taus = carry
        mask = rows >= j
        x = jnp.where(mask, acc[:, j], 0.0)
        norm = jnp.linalg.norm(x)
        x1 = x[j]
        s = jnp.where(x1 >= 0, 1.0, -1.0)
        pivot = -s * norm
        v1 = x1 + s * norm
        safe = jnp.abs(v1) > 0
        v = jnp.where(mask, x / jnp.where(safe, v1, 1.0), 0.0)
        v = v.at[j].set(jnp.where(safe, 1.0, 0.0))
        tau = jnp.where(safe, s * v1 / jnp.where(norm == 0, 1.0, norm), 0.0)
        w = v @ acc
        acc = acc - tau * jnp.outer(v, w)
        acc = acc.at[j, j].set(pivot)
        return acc, vs.at[:, j].set(v), taus.at[j].set(tau)

    acc, vs, taus = jax.lax.fori_loop(0, b, step, (a, vs, taus))
    r = jnp.triu(acc[:b, :])
    return vs, taus, r
