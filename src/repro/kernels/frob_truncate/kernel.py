"""δ-truncation kernel — the TRUNCATION module on TPU.

The paper's TRUNCATION module walks the tail of the singular-value vector,
forms the error vector e, and checks ‖e‖₂ > δ, decrementing the rank until
the accuracy target holds.  The vectorized equivalent is one reverse
cumulative sum of squares (the whole FSM collapses into a scan) followed by
a thresholded argmax — a single VMEM pass.

Outputs: tail norms t[i] = ‖σ[i:]‖₂ and the paper's kept rank r
(smallest 1-indexed i with t[i] < δ; everything if none).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _truncate_kernel(s_ref, delta_ref, tail_ref, rank_ref, *, n):
    s = s_ref[0, :].astype(jnp.float32)
    delta = delta_ref[0, 0]
    sq = s * s
    tail_sq = jnp.cumsum(sq[::-1])[::-1]
    tail = jnp.sqrt(tail_sq)
    cond = tail < delta
    any_hit = jnp.any(cond)
    first = jnp.argmax(cond)
    rank = jnp.where(any_hit, jnp.maximum(first + 1, 1), n)
    tail_ref[0, :] = tail
    rank_ref[0, 0] = jnp.clip(rank, 1, n).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def frob_truncate(s: jax.Array, delta, interpret: bool = False):
    """Returns (tail_norms (n,), rank scalar int32) for σ vector ``s``."""
    n = s.shape[0]
    kern = functools.partial(_truncate_kernel, n=n)
    tail, rank = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ),
        interpret=interpret,
    )(
        s[None, :].astype(jnp.float32),
        jnp.asarray(delta, jnp.float32).reshape(1, 1),
    )
    return tail[0], rank[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def frob_truncate_batched(s: jax.Array, delta, interpret: bool = False):
    """δ-truncate each row of a (B, n) σ stack in ONE kernel launch.

    ``delta`` is (B,) — each grid program applies its own member's budget.
    Returns (tail_norms (B,n), ranks (B,) int32); member k equals
    ``frob_truncate(s[k], delta[k])``.
    """
    bsz, n = s.shape
    kern = functools.partial(_truncate_kernel, n=n)
    tail, rank = pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
        ),
        interpret=interpret,
    )(
        s.astype(jnp.float32),
        jnp.asarray(delta, jnp.float32).reshape(bsz, 1),
    )
    return tail, rank[:, 0]
