"""Oracle for the δ-truncation kernel: repro.core.truncation semantics."""

import jax
import jax.numpy as jnp

from repro.core import truncation as _trunc


def frob_truncate_ref(s: jax.Array, delta):
    tail = _trunc.tail_norms(s.astype(jnp.float32))
    rank = _trunc.truncation_rank_static(s.astype(jnp.float32), delta)
    return tail, rank
