"""jit'd wrapper for the δ-truncation kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels import common
from repro.kernels.frob_truncate.kernel import frob_truncate as _kernel
from repro.kernels.frob_truncate.ref import frob_truncate_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_truncate(s: jax.Array, delta, interpret: bool | None = None):
    """(tail_norms, rank) under the paper's δ rule (Alg. 1 line 28)."""
    if interpret is None:
        interpret = common.use_interpret()
    return _kernel(s, delta, interpret=interpret)


__all__ = ["delta_truncate", "frob_truncate_ref"]
