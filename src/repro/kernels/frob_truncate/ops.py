"""jit'd wrapper for the δ-truncation kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels import common
from repro.kernels.frob_truncate.kernel import frob_truncate as _kernel
from repro.kernels.frob_truncate.kernel import (
    frob_truncate_batched as _kernel_batched,
)
from repro.kernels.frob_truncate.ref import frob_truncate_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_truncate(s: jax.Array, delta, interpret: bool | None = None):
    """(tail_norms, rank) under the paper's δ rule (Alg. 1 line 28)."""
    if interpret is None:
        interpret = common.use_interpret()
    return _kernel(s, delta, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_truncate_batched(s: jax.Array, delta, interpret: bool | None = None):
    """One launch δ-truncating every row of a (B, n) σ stack; delta is (B,)."""
    if interpret is None:
        interpret = common.use_interpret()
    return _kernel_batched(s, delta, interpret=interpret)


__all__ = ["delta_truncate", "delta_truncate_batched", "frob_truncate_ref"]
