"""jax-version compatibility shims — single source of truth.

The repo supports jax from 0.4.35 (the pinned container toolchain) through
current releases; three API moves land in that range and were previously
shimmed ad hoc at each call site (``models/mlp.py``, ``core/comm_compress.py``,
``launch/mesh.py``).  They live here now so a fourth caller can never drift:

  * ``shard_map``  — top-level ``jax.shard_map`` (+ ``check_vma``) vs
                     ``jax.experimental.shard_map`` (+ ``check_rep``);
  * ``pvary``      — explicit axis-varying marking (newer jax requires it
                     inside shard_map bodies; older jax has no such concept);
  * ``make_mesh``  — ``jax.make_mesh`` with Auto axis types where
                     ``jax.sharding.AxisType`` exists (post-0.4.37), plain
                     Auto meshes before explicit-sharding mode.
"""

from __future__ import annotations

import jax


def shard_map(body, mesh, in_specs, out_specs):
    """``jax.shard_map`` (with VMA checking off) across jax versions: the
    top-level entry + ``check_vma`` landed after 0.4.x, where the API lives
    in ``jax.experimental.shard_map`` and the flag is ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def pvary(x, axis_names):
    """``jax.lax.pvary`` where it exists (newer jax: values produced inside a
    shard_map body must be marked varying over the axes they'll reduce
    over); identity on older jax, which has no VMA tracking."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is not None:
        return fn(x, tuple(axis_names))
    return x


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the installed jax
    supports them (``jax.sharding.AxisType`` landed after 0.4.37; older
    jaxlibs predate explicit-sharding mode entirely, so plain Auto meshes
    are the correct fallback)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)
