"""Fault tolerance: restart policy, straggler mitigation, failure simulation.

On a 1000+-node fleet the failure model is: chips/hosts fail mid-step
(XLA raises, the coordinator loses a heartbeat), stragglers stretch step
time, and capacity changes (preemption / repair) resize the usable mesh.
The control plane here implements the standard production responses:

  * ``RestartPolicy``     — bounded restarts with exponential backoff;
    restore from the newest committed checkpoint; deterministic data
    skip-ahead (the pipeline is a pure function of step, so no replay log).
  * ``StragglerMonitor``  — EWMA step-time tracker; flags steps beyond
    k·σ and counts per-host incidents so the launcher can cordon a host
    (on TPU pods a straggler is usually a host, not a chip).
  * ``ElasticPlan``       (runtime/elastic.py) — recompute the mesh and
    shardings for a changed device count; checkpoint restore absorbs the
    re-shard (checkpoint/checkpoint.py saves unsharded values).
  * ``simulate_failures`` — deterministic failure injector used by the
    integration tests to prove train-loop recovery end-to-end.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 1.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 300.0

    def run(self, make_loop: Callable[[int], int], log=print) -> int:
        """make_loop(start_step) -> last_step, raising on simulated/real
        failure.  Returns the final step reached."""
        restarts = 0
        last_step = 0
        while True:
            try:
                return make_loop(last_step)
            except TrainingFailure as e:
                restarts += 1
                last_step = e.resume_step
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts"
                    ) from e
                delay = min(
                    self.backoff_s * self.backoff_factor ** (restarts - 1),
                    self.max_backoff_s,
                )
                log(f"[ft] failure at step {e.step} ({e.reason}); "
                    f"restart #{restarts} from step {e.resume_step} "
                    f"after {delay:.1f}s backoff")
                time.sleep(min(delay, 0.01))  # tests: don't actually sleep


class TrainingFailure(Exception):
    def __init__(self, step: int, resume_step: int, reason: str):
        super().__init__(f"step {step}: {reason}")
        self.step = step
        self.resume_step = resume_step
        self.reason = reason


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA + variance tracker over step times; straggler = step beyond
    ``sigma_k`` standard deviations (and above an absolute floor)."""
    alpha: float = 0.1
    sigma_k: float = 3.0
    min_steps: int = 8
    floor_ratio: float = 1.5

    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    incidents: Dict[str, int] = dataclasses.field(default_factory=dict)

    def observe(self, step_time_s: float, host: str = "host0") -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.n += 1
        if self.n == 1:
            self.mean = step_time_s
            self.var = 0.0
            return False
        d = step_time_s - self.mean
        flagged = False
        if self.n > self.min_steps:
            sigma = math.sqrt(max(self.var, 1e-12))
            if (step_time_s > self.mean + self.sigma_k * sigma
                    and step_time_s > self.floor_ratio * self.mean):
                flagged = True
                self.incidents[host] = self.incidents.get(host, 0) + 1
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return flagged

    def cordon_candidates(self, threshold: int = 3) -> List[str]:
        return [h for h, c in self.incidents.items() if c >= threshold]


def simulate_failures(fail_steps: Dict[int, str]):
    """Decorator-ish injector: raise TrainingFailure when step hits a key.
    Used by tests/integration to drive RestartPolicy."""
    fired = set()

    def check(step: int, resume_step: int):
        if step in fail_steps and step not in fired:
            fired.add(step)
            raise TrainingFailure(step, resume_step, fail_steps[step])

    return check
