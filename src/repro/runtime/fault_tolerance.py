"""Fault tolerance: restart policy, straggler mitigation, failure simulation.

On a 1000+-node fleet the failure model is: chips/hosts fail mid-step
(XLA raises, the coordinator loses a heartbeat), stragglers stretch step
time, and capacity changes (preemption / repair) resize the usable mesh.
The control plane here implements the standard production responses:

  * ``RestartPolicy``     — bounded restarts with exponential backoff;
    restore from the newest committed checkpoint; deterministic data
    skip-ahead (the pipeline is a pure function of step, so no replay log).
  * ``StragglerMonitor``  — EWMA step-time tracker; flags steps beyond
    k·σ and counts per-host incidents so the launcher can cordon a host
    (on TPU pods a straggler is usually a host, not a chip).
  * ``ElasticPlan``       (runtime/elastic.py) — recompute the mesh and
    shardings for a changed device count; checkpoint restore absorbs the
    re-shard (checkpoint/checkpoint.py saves unsharded values).
  * ``simulate_failures`` — deterministic failure injector used by the
    integration tests to prove train-loop recovery end-to-end.
"""

from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class RestartPolicy:
    """Bounded restarts with exponential backoff.

    ``sleep`` is injectable so tests (and the serving chaos lane) can run
    the policy with a no-op while production keeps the FULL computed
    delay — the backoff math and what actually gets slept are the same
    code path either way.
    """
    max_restarts: int = 10
    backoff_s: float = 1.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 300.0
    sleep: Callable[[float], None] = time.sleep

    def backoff(self, restart_index: int) -> float:
        """Delay before restart #``restart_index`` (1-based), capped at
        ``max_backoff_s``."""
        return min(
            self.backoff_s * self.backoff_factor ** (restart_index - 1),
            self.max_backoff_s,
        )

    def run(self, make_loop: Callable[[int], int], log=print) -> int:
        """make_loop(start_step) -> last_step, raising on simulated/real
        failure.  Returns the final step reached."""
        restarts = 0
        last_step = 0
        while True:
            try:
                return make_loop(last_step)
            except TrainingFailure as e:
                restarts += 1
                last_step = e.resume_step
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts"
                    ) from e
                delay = self.backoff(restarts)
                log(f"[ft] failure at step {e.step} ({e.reason}); "
                    f"restart #{restarts} from step {e.resume_step} "
                    f"after {delay:.1f}s backoff")
                self.sleep(delay)


class TrainingFailure(Exception):
    def __init__(self, step: int, resume_step: int, reason: str):
        super().__init__(f"step {step}: {reason}")
        self.step = step
        self.resume_step = resume_step
        self.reason = reason


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA + variance tracker over step times; straggler = step beyond
    ``sigma_k`` standard deviations (and above an absolute floor)."""
    alpha: float = 0.1
    sigma_k: float = 3.0
    min_steps: int = 8
    floor_ratio: float = 1.5

    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    incidents: Dict[str, int] = dataclasses.field(default_factory=dict)

    def observe(self, step_time_s: float, host: str = "host0") -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.n += 1
        if self.n == 1:
            self.mean = step_time_s
            self.var = 0.0
            return False
        d = step_time_s - self.mean
        flagged = False
        if self.n > self.min_steps:
            sigma = math.sqrt(max(self.var, 1e-12))
            if (step_time_s > self.mean + self.sigma_k * sigma
                    and step_time_s > self.floor_ratio * self.mean):
                flagged = True
                self.incidents[host] = self.incidents.get(host, 0) + 1
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return flagged

    def cordon_candidates(self, threshold: int = 3) -> List[str]:
        return [h for h, c in self.incidents.items() if c >= threshold]


class InjectedFault(RuntimeError):
    """Raised by a ``FaultPlan`` crash hook inside a replica worker — the
    deterministic stand-in for an XLA/driver failure killing the thread."""


@dataclasses.dataclass
class FaultPlan:
    """Seeded, deterministic fault-injection plan for the serving plane.

    The training loop has ``simulate_failures``; this is the serving
    equivalent, consumed by ``launch/router.py`` (per-replica ``fault_hook``
    called with the replica's worked-chunk counter) and by
    ``benchmarks/chaos_serve.py``:

      * ``crash_at``  — replica index → chunk index at which that replica's
        worker raises ``InjectedFault`` (fires once; a restarted replica
        does not re-crash);
      * ``stall_at``  — replica index → ``(chunk index, seconds)``: the
        worker sleeps before that chunk — a slow-chunk straggler that trips
        the router's watchdog (``SUSPECT``) and then recovers;
      * ``poison``    — request trace indices served with NaN logits (the
        chaos lane plants the magic poison token in those prompts);
      * ``corrupt_checkpoint`` — whether the checkpoint-integrity leg
        rewrites a committed shard with wrong bytes.

    Same seed ⇒ same plan ⇒ same injection points: the chaos lane is
    reproducible run to run.
    """
    crash_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    stall_at: Dict[int, Tuple[int, float]] = dataclasses.field(
        default_factory=dict)
    poison: Tuple[int, ...] = ()
    corrupt_checkpoint: bool = False
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self):
        self._fired: set = set()

    @classmethod
    def seeded(cls, seed: int, replicas: int, requests: int,
               crashes: int = 1, stalls: int = 1, poisons: int = 1,
               stall_s: float = 1.0, span: int = 6) -> "FaultPlan":
        """Draw a plan from ``seed``: ``crashes`` replicas die and
        ``stalls`` (different) replicas straggle at chunk indices in
        ``[1, span)``; ``poisons`` of the ``requests`` trace entries are
        NaN-poisoned."""
        rng = random.Random(seed)
        reps = list(range(replicas))
        rng.shuffle(reps)
        crashing = reps[:min(crashes, replicas)]
        stalling = reps[len(crashing):] or reps
        plan = cls(
            crash_at={r: rng.randrange(1, span) for r in crashing},
            stall_at={r: (rng.randrange(1, span), stall_s)
                      for r in stalling[:min(stalls, len(stalling))]},
            poison=tuple(sorted(rng.sample(range(requests),
                                           min(poisons, requests)))),
            corrupt_checkpoint=True,
        )
        return plan

    def hook_for(self, replica: int) -> Callable[[int], None]:
        """The per-replica hook the router calls with its worked-chunk
        counter.  Each injection fires exactly once per plan instance."""
        def hook(chunk: int) -> None:
            stall = self.stall_at.get(replica)
            if (stall is not None and chunk >= stall[0]
                    and ("stall", replica) not in self._fired):
                self._fired.add(("stall", replica))
                self.sleep(stall[1])
            if (replica in self.crash_at
                    and chunk >= self.crash_at[replica]
                    and ("crash", replica) not in self._fired):
                self._fired.add(("crash", replica))
                raise InjectedFault(
                    f"fault plan: replica {replica} crash at chunk {chunk}")
        return hook

    def counts(self) -> Dict[str, int]:
        """Planned injection counts (what BENCH_chaos.json records)."""
        return {
            "crashes": len(self.crash_at),
            "stalls": len(self.stall_at),
            "poisoned_requests": len(self.poison),
            "corrupt_checkpoints": int(self.corrupt_checkpoint),
        }

    def fired(self) -> Dict[str, int]:
        """How many planned injections actually fired."""
        return {
            "crashes": sum(1 for k in self._fired if k[0] == "crash"),
            "stalls": sum(1 for k in self._fired if k[0] == "stall"),
        }


def simulate_failures(fail_steps: Dict[int, str]):
    """Decorator-ish injector: raise TrainingFailure when step hits a key.
    Used by tests/integration to drive RestartPolicy."""
    fired = set()

    def check(step: int, resume_step: int):
        if step in fail_steps and step not in fired:
            fired.add(step)
            raise TrainingFailure(step, resume_step, fail_steps[step])

    return check
