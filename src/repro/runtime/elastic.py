"""Elastic scaling: recompute mesh + shardings when the device pool changes.

The framework's invariants make elasticity cheap:
  * checkpoints store unsharded values (restore re-shards onto any mesh);
  * the data pipeline is a pure function of (step, shard, num_shards);
  * sharding specs are derived from config + mesh, not baked into state.

``plan_mesh`` picks the largest usable (data × model) grid for a device
count, preferring to keep the model axis stable (changing TP degree
invalidates more compiled artifacts than changing DP degree).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_devices: int
    changed: bool

    def build(self, devices=None) -> Mesh:
        devs = devices if devices is not None else jax.devices()
        n = 1
        for s in self.mesh_shape:
            n *= s
        import numpy as np
        arr = np.asarray(devs[:n]).reshape(self.mesh_shape)
        return Mesh(arr, self.axis_names)


def plan_mesh(
    available: int,
    model_parallel: int,
    prev_shape: Optional[Tuple[int, int]] = None,
    min_data: int = 1,
) -> ElasticPlan:
    """Largest (data, model) grid with the requested TP degree; falls back
    to smaller TP only if the pool is smaller than one model group."""
    mp = model_parallel
    while mp > 1 and available < mp:
        mp //= 2
    data = max(available // mp, min_data)
    used = data * mp
    changed = prev_shape is not None and prev_shape != (data, mp)
    return ElasticPlan(
        mesh_shape=(data, mp),
        axis_names=("data", "model"),
        dropped_devices=available - used,
        changed=changed,
    )


def reshard_batch_assignment(
    global_batch: int, num_shards: int
) -> List[Tuple[int, int]]:
    """(start, count) per shard — pure arithmetic, drives the data pipeline
    after an elastic resize."""
    base = global_batch // num_shards
    rem = global_batch % num_shards
    out = []
    start = 0
    for i in range(num_shards):
        c = base + (1 if i < rem else 0)
        out.append((start, c))
        start += c
    return out
