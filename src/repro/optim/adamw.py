"""AdamW + gradient clipping + schedules — self-contained (no optax).

States are fp32 and sharded like the parameters (with FSDP forced on for
states even when params replicate — ZeRO-1 semantics; see
launch/sharding.opt variant).  The optimizer exposes the standard
(init, update) pair plus a ``state_specs`` helper for pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any                        # first moment (params-shaped, fp32)
    nu: Any                        # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        def zeros():
            return jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=zeros(),
            nu=zeros(),   # distinct buffers (donation requires no aliasing)
        )

    def lr_at(self, step) -> jax.Array:
        if callable(self.learning_rate):
            return jnp.asarray(self.learning_rate(step), jnp.float32)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(
        self, grads, state: AdamWState, params
    ) -> Tuple[Any, AdamWState]:
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gnorm = global_norm(g32)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(
            lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, g32
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr_at(step)

        def upd(p, m, n):
            mh = m / bc1
            nh = n / bc2
            u = mh / (jnp.sqrt(nh) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(
    peak: float, warmup_steps: int, total_steps: int, floor: float = 0.1
) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1
        )
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def linear_warmup(peak: float, warmup_steps: int) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        return peak * jnp.minimum(1.0, step / max(warmup_steps, 1))
    return fn
