"""Sharding rules: path/shape-pattern → PartitionSpec, per family.

Megatron-style tensor parallelism on the ``model`` axis + optional ZeRO-3
FSDP on the ``data`` axis; the ``pod`` axis is pure data parallelism (its
gradient sync is the paper-relevant slow link, optionally TT-compressed).

The rules operate on the *path names* of the parameter pytree (NamedTuple
field names), so one table covers every architecture:

  attention   wq (L,D,H,Dh)→ heads on model     wo (L,H,Dh,D)→ heads on model
  mlp         w_gate/w_up (L,D,F)→ F on model   w_down (L,F,D)→ F on model
  moe         experts (L,E,D,F)→ E on model (EP)
  mamba/rglru inner width on model
  embeddings  vocab on model
  norms/bias  replicated (tiny)

FSDP (when cfg.fsdp) additionally shards the non-model embed/hidden dim of
big tensors over ``data``.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (path regex, ndim -> PartitionSpec builder).  {m}=model axis, {f}=fsdp axis.
# Specs written for the LAYER-STACKED tensors (leading L axis) — the leading
# None is dropped automatically for unstacked tensors of rank-1 lower.
_RULES = [
    # --- embeddings / unembeddings: (V, D) ---
    (r"(embed|lm_head)$", lambda m, f: P(m, f)),
    # --- attention ---
    (r"attn\.wq$|self_attn\.wq$|cross_attn\.wq$", lambda m, f: P(None, f, m, None)),
    (r"attn\.wk$|self_attn\.wk$|cross_attn\.wk$", lambda m, f: P(None, f, m, None)),
    (r"attn\.wv$|self_attn\.wv$|cross_attn\.wv$", lambda m, f: P(None, f, m, None)),
    (r"attn\.wo$|self_attn\.wo$|cross_attn\.wo$", lambda m, f: P(None, m, None, f)),
    (r"attn\.b[qkv]$", lambda m, f: P(None, m, None)),
    (r"attn\.(q|k)_norm$", lambda m, f: P(None, None)),
    # --- dense MLP ---
    (r"mlp\.w_gate$|mlp\.w_up$", lambda m, f: P(None, f, m)),
    (r"mlp\.w_down$", lambda m, f: P(None, m, f)),
    # --- MoE (expert parallel) ---
    (r"moe\.router$", lambda m, f: P(None, f, None)),
    (r"moe\.w_gate$|moe\.w_up$", lambda m, f: P(None, m, f, None)),
    (r"moe\.w_down$", lambda m, f: P(None, m, None, f)),
    # --- Mamba-2 ---
    (r"\.w_in$", lambda m, f: P(None, f, m)),
    (r"\.conv_w$", lambda m, f: P(None, None, m)),
    (r"\.conv_b$", lambda m, f: P(None, m)),
    (r"\.(a_log|d_skip|dt_bias)$", lambda m, f: P(None, m)),
    (r"\.gate_norm$", lambda m, f: P(None, m)),
    (r"\.w_out$", lambda m, f: P(None, m, f)),
    # --- RG-LRU ---
    (r"\.w_x$|\.w_gate$", lambda m, f: P(None, f, m)),
    (r"\.(lam|b_rg|b_ig)$", lambda m, f: P(None, m)),
    (r"\.w_rg$|\.w_ig$", lambda m, f: P(None, None, m)),
    # --- norms ---
    (r"(ln\d?|ln_x|final_norm|enc_norm|ln)$", lambda m, f: P(None, None)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def spec_for(path: str, shape, cfg, model_axis="model",
             fsdp_axis="data") -> P:
    """PartitionSpec for one parameter."""
    f_ax = fsdp_axis if cfg.fsdp else None
    if getattr(cfg, "opt_moe_tp", False) and re.search(r"moe\.w_", path):
        # §Perf (dbrx): TP-sharded experts — d_ff on the model axis, experts
        # replicated; the FFN contraction then needs a single (cap, D)
        # all-reduce rather than d_ff-wide partial sums.
        if re.search(r"moe\.w_gate$|moe\.w_up$", path):      # (L,E,D,F)
            return _fit(P(None, None, f_ax, model_axis), len(shape), shape)
        if re.search(r"moe\.w_down$", path):                  # (L,E,F,D)
            return _fit(P(None, None, model_axis, f_ax), len(shape), shape)
    for pat, builder in _RULES:
        if re.search(pat, path):
            spec = builder(model_axis, f_ax)
            spec = _fit(spec, len(shape), shape)
            return spec
    # default: replicate
    return P(*([None] * len(shape)))


def _fit(spec: P, ndim: int, shape) -> P:
    """Adapt a stacked-layer spec to the actual rank and drop axes that do
    not divide the dimension."""
    parts = list(spec)
    if len(parts) == ndim + 1 and parts[0] is None:
        parts = parts[1:]                      # unstacked variant
    while len(parts) < ndim:
        parts.append(None)
    parts = parts[:ndim]
    # divisibility guard: never emit a spec a dim can't honor
    out = []
    for dim, ax in zip(shape, parts):
        if ax is None:
            out.append(None)
            continue
        size = _axis_size(ax)
        out.append(ax if size is not None and dim % size == 0 else None)
    return P(*out)


_MESH_SIZES = {}
_CURRENT_MESH = None


def set_mesh_axis_sizes(mesh: Mesh):
    global _MESH_SIZES, _CURRENT_MESH
    _MESH_SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))
    _CURRENT_MESH = mesh


def current_mesh() -> Optional[Mesh]:
    """The mesh registered by the launcher (for explicit shard_map regions)."""
    return _CURRENT_MESH


def _axis_size(ax) -> Optional[int]:
    if isinstance(ax, tuple):
        sizes = [_MESH_SIZES.get(a) for a in ax]
        if any(s is None for s in sizes):
            return None
        return int(np.prod(sizes))
    return _MESH_SIZES.get(ax)


def param_specs(params_shape, cfg, model_axis="model", fsdp_axis="data"):
    """PartitionSpec pytree matching a params (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [
        spec_for(_path_str(path), leaf.shape, cfg, model_axis, fsdp_axis)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_shape, data_axes=("pod", "data")):
    """Batch pytree: leading dim over (pod, data); embeddings stubs too.
    Dims that don't divide the axis product (e.g. batch=1 at long_500k)
    fall back to replication via the _fit guard."""
    def one(leaf):
        nd = len(leaf.shape)
        return _fit(P(data_axes, *([None] * (nd - 1))), nd, leaf.shape)
    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape, cfg, data_axes=("pod", "data"),
                model_axis="model"):
    """Decode-cache sharding: batch over data axes; the long sequence axis
    over the model axis (flash-decode/sequence-parallel, DESIGN.md §4);
    recurrent states shard their width over model."""
    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        if name.endswith("pos"):
            return P()
        if name in ("k", "v", "mem_k", "mem_v"):
            # (L, B, S, Hkv, Dh): batch over data, seq over model
            spec = P(None, data_axes, model_axis, None, None)
            return _fit(spec, nd, shape)
        if "conv" in name:
            return _fit(P(None, data_axes, None, model_axis), nd, shape)
        if name.startswith("h") or name == "ssm_state":
            # recurrent state: (L, B, R) / (L, B, H, N, P)
            spec = P(None, data_axes, model_axis, None, None)
            return _fit(spec, nd, shape)
        return _fit(P(None, data_axes), nd, shape)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = [one(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def act_constraint(x, *axes):
    """with_sharding_constraint for ACTIVATIONS, tolerant of absent axes.

    axes: one mesh-axis name (or None) per dim of x.  Axes missing from the
    current mesh, or not dividing the dim, are dropped (no-op on host
    meshes) — so model code can state intent unconditionally.
    """
    parts = []
    for dim, ax in zip(x.shape, axes):
        size = _axis_size(ax) if ax is not None else None
        parts.append(ax if (size and dim % size == 0 and size > 1) else None)
    if all(p is None for p in parts):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except (ValueError, RuntimeError):
        return x                     # no mesh in context (plain jit)
