"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh, constructs
ShapeDtypeStruct stand-ins for params/optimizer/batch/cache (no device
allocation), jits the appropriate step with explicit in/out shardings,
``.lower().compile()``s it, and records:

  * memory_analysis()  — per-device bytes (proves the cell fits HBM)
  * cost_analysis()    — per-device FLOPs / bytes for §Roofline
  * collective schedule (parsed from the partitioned HLO)

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results/
"""

# must happen before jax is imported (below) so the placeholder devices exist
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import NAME_TO_MODULE, get_config
from repro.configs.base import SHAPES, applicable_shapes
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh, batch_axes
from repro.launch.specs import input_specs, params_shape
from repro.models.registry import build
from repro.optim.adamw import AdamW, cosine_schedule
from repro.roofline import analysis as roofline
from repro.roofline import hlo_walk as _hlo_walk
from repro.train.steps import TrainState, make_train_step, make_prefill_step


def _sds(tree):
    """Pytree → ShapeDtypeStructs with shardings attached."""
    return tree


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               save_hlo: str | None = None, impl: str = "xla",
               opts: str = "", microbatch: int | None = None):
    cfg = get_config(arch)
    if opts:
        cfg = cfg.with_opts(opts.split(","))
    if microbatch:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, microbatch=microbatch)
    shape = SHAPES[shape_name]
    if shape_name not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch: long_500k not applicable "
                          "(DESIGN.md §6)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    shd.set_mesh_axis_sizes(mesh)
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    _hlo_walk.set_pod_size(mesh.devices.size // n_pods)
    baxes = batch_axes(mesh)
    model = build(cfg)
    optimizer = AdamW(learning_rate=cosine_schedule(3e-4, 100, 10_000))
    cell = input_specs(cfg, shape, optimizer if shape.kind == "train" else None)

    p_specs = shd.param_specs(cell.params, cfg)
    b_specs = shd.batch_specs(cell.batch, data_axes=baxes)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt_specs = _opt_specs(mesh, cell.opt, cell.params, cfg)
            step = make_train_step(model, optimizer, batch_axes=baxes,
                                   impl=impl)
            in_sh = (
                TrainState(params=shd.named(mesh, p_specs),
                           opt=opt_specs),
                shd.named(mesh, b_specs),
            )
            state_spec = TrainState(params=cell.params, opt=cell.opt)
            lowered = jax.jit(
                step, in_shardings=in_sh,
                donate_argnums=(0,),
            ).lower(state_spec, cell.batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, impl=impl)
            in_sh = (shd.named(mesh, p_specs), shd.named(mesh, b_specs))
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                cell.params, cell.batch
            )
        else:  # decode
            c_specs = shd.cache_specs(cell.cache, cfg, data_axes=baxes)
            tok_spec = shd.batch_specs(cell.batch, data_axes=baxes)["tokens"]
            in_sh = (
                shd.named(mesh, p_specs),
                shd.named(mesh, c_specs),
                NamedSharding(mesh, tok_spec),
            )
            lowered = jax.jit(
                model.decode_step, in_shardings=in_sh, donate_argnums=(1,),
            ).lower(cell.params, cell.cache, cell.batch["tokens"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof = roofline.analyze(compiled, hlo).to_dict()
    mf = roofline.model_flops(cfg, shape)
    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gib": mem.argument_size_in_bytes / 2**30,
            "output_gib": mem.output_size_in_bytes / 2**30,
            "temp_gib": mem.temp_size_in_bytes / 2**30,
            "peak_ok": (
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ) < 16 * 2**30,
        },
        "roofline": roof,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (
            (mf / n_dev) / roof["flops"] if roof["flops"] else None
        ),
    }
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    return result


def _opt_specs(mesh, opt_shape, p_shape, cfg):
    """Optimizer-state shardings: ZeRO — FSDP forced on for the moments even
    when the params themselves replicate (the states are 4× bigger)."""
    import dataclasses as _dc
    from repro.optim.adamw import AdamWState
    zcfg = _dc.replace(cfg, fsdp=True)
    moment_specs = shd.param_specs(p_shape, zcfg)
    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=shd.named(mesh, moment_specs),
        nu=shd.named(mesh, moment_specs),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--save-hlo", type=str, default=None)
    ap.add_argument("--opt", type=str, default="",
                    help="comma-separated opt_<name> flags (§Perf hillclimbs)")
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in NAME_TO_MODULE:
            for shape in applicable_shapes(get_config(arch)):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        try:
            r = lower_cell(arch, shape, args.multi_pod, args.save_hlo,
                           opts=args.opt, microbatch=args.microbatch)
            status = "SKIP" if r.get("skipped") else "OK"
        except Exception as e:
            traceback.print_exc()
            r = {"arch": arch, "shape": shape, "error": str(e)[-2000:],
                 "multi_pod": args.multi_pod}
            status = "FAIL"
        results.append(r)
        print(f"[{status}] {arch} × {shape} "
              f"(multi_pod={args.multi_pod})", flush=True)
        if status == "OK":
            roof = r["roofline"]
            print(f"    compile={r['compile_s']}s "
                  f"mem(arg={r['memory']['argument_gib']:.2f}GiB "
                  f"temp={r['memory']['temp_gib']:.2f}GiB) "
                  f"compute={roof['compute_s']*1e3:.2f}ms "
                  f"memory={roof['memory_s']*1e3:.2f}ms "
                  f"collective={roof['collective_s']*1e3:.2f}ms "
                  f"bottleneck={roof['bottleneck']}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if "error" in r]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
