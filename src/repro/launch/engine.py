"""Serving engine: fused on-device decode driver + continuous batching.

Two layers, both family-agnostic (they only touch the uniform
``decode_step(params, cache, tokens) -> (logits, cache)`` /
``init_cache(batch, max_len)`` Model surface):

``generate(model, params, prompts, gen, driver=...)``
    One uniform batch, two drivers:

    * ``python`` — the legacy oracle: one jitted ``decode_step`` per token,
      driven from Python.  Pays a host→device dispatch round-trip plus a
      host sync (the sample/argmax readback) per token.
    * ``fused``  — the whole generation (prefill-by-stepping → sample →
      append → step) runs as ONE jitted ``lax.scan`` per phase
      (``models.common.gen_scan``), with the state donated between phases.
      TT cores stay closure constants of the scanned body exactly as in
      ``common.tt_scan`` — the device never waits on Python between tokens.

    Sampling (greedy, or temperature/top-k under per-row PRNG streams) and
    encoder input for encdec families (``src_tokens`` → memory populated
    before the first decode step) are part of the shared contract — the
    two drivers stay token-for-token identical under both.

``Engine``
    Continuous batching on top of the fused driver: a slot-based cache
    pool with per-slot lengths.  Requests with heterogeneous prompt/gen
    lengths are admitted into finished slots between fused chunks, prefill
    is chunked across those boundaries (a freshly admitted slot consumes
    its prompt tokens while neighbours keep decoding), and finished slots
    are harvested and refilled — the pool stays at high occupancy instead
    of padded-batch lockstep.  Encdec requests carry their source through
    ``submit(..., src_tokens=...)``; admission runs the encode and fills
    the slot's cross-attention memory rows.
"""

from __future__ import annotations

import functools
import time
from collections import deque
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as model_common

DRIVERS = ("fused", "python")


def _decode_fn(model):
    return jax.jit(model.decode_step, donate_argnums=(1,))


def _python_loop(decode, params, cache, prompts, gen,
                 sampling=model_common.GREEDY, keys=None):
    """Legacy one-jitted-step-per-token loop (the ``--driver python``
    oracle).  Prefills by stepping the decode cache through the prompt,
    then decodes ``gen`` tokens — greedy, or sampled under the SAME
    per-row ``fold_in(keys[row], t)`` streams the fused driver uses, so
    the oracle stays token-for-token even under stochastic sampling.  Each
    token pays a dispatch plus the sample/argmax host sync."""
    b, prompt_len = prompts.shape
    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, cache = decode(params, cache, jnp.asarray(prompts[:, i:i+1]))
    jax.block_until_ready(logits)
    prefill_t = time.time() - t0
    prompt_logits = logits

    def pick(logits, t):
        if sampling.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        keys_t = jax.vmap(jax.random.fold_in)(
            keys, jnp.full((b,), t, jnp.int32))
        return model_common.sample_tokens(logits, keys_t, sampling)[:, None]

    tok = pick(logits, 0)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for t in range(1, gen):
        logits, cache = decode(params, cache, tok)
        tok = pick(logits, t)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_t = time.time() - t0
    return {
        "prefill_t": prefill_t,
        "decode_t": decode_t,
        "gen": np.concatenate(out_tokens, axis=1),
        "prompt_logits": prompt_logits,
    }


@functools.partial(jax.jit, static_argnums=(0, 3, 4), donate_argnums=(2,))
def _run_steps(decode_step, params, state, n_steps,
               sampling=model_common.GREEDY):
    """``n_steps`` fused decode steps, state donated across chunk calls so
    the cache pool is updated in place between Python-side admissions."""
    return model_common.gen_scan(decode_step, params, state, n_steps,
                                 sampling)


def _fused_generate(model, params, cache, prompts, gen,
                    sampling=model_common.GREEDY, keys=None):
    """Whole-generation fused driver: two scanned phases (prefill, decode)
    so the timing split matches the python loop's reporting boundaries."""
    decode = model.decode_step            # raw step: scanned, not re-jitted
    b, prompt_len = prompts.shape
    t_max = int(prompt_len + gen)
    tokens = np.zeros((b, t_max), np.int32)
    tokens[:, :prompt_len] = prompts
    state = model_common.gen_init(
        cache, tokens, prompt_len, t_max, model.cfg.padded_vocab_size,
        rng=keys,
    )
    t0 = time.time()
    state = _run_steps(decode, params, state, prompt_len, sampling)
    state = jax.block_until_ready(state)
    prefill_t = time.time() - t0
    t0 = time.time()
    if gen > 1:
        state = _run_steps(decode, params, state, gen - 1, sampling)
        state = jax.block_until_ready(state)
    decode_t = time.time() - t0
    return {
        "prefill_t": prefill_t,
        "decode_t": decode_t,
        "gen": np.asarray(state.tokens[:, prompt_len:]),
        "prompt_logits": state.prompt_logits,
    }


def generate(model, params, prompts, gen: int, max_len: Optional[int] = None,
             driver: str = "fused", decode=None, src_tokens=None,
             temperature: float = 0.0, top_k: Optional[int] = None,
             seed: int = 0) -> dict:
    """One uniform-batch serving run; single source of truth for
    prefill-by-stepping + sampling + timing boundaries.

    Returns ``{prefill_t, decode_t, gen (B, gen) np.int32, prompt_logits}``
    — identical contract (and, token for token, identical output) for both
    drivers.  ``decode`` lets python-driver callers share one jitted step
    across runs (the fused driver keys its compile cache on
    ``model.decode_step`` itself and needs no sharing).

    ``src_tokens`` — optional encoder input for encoder-decoder families:
    (S_src,) shared across the batch or (B, S_src) per row; encoded once up
    front and written into the cache's cross-attention memory
    (``model.populate_memory``) before any decode step runs.

    ``temperature``/``top_k``/``seed`` — stochastic sampling.  Row ``r``
    samples under ``fold_in(PRNGKey(seed), r)``; ``temperature=0`` (the
    default) is greedy argmax, bit-identical to the pre-sampling driver.
    """
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r} (choose from {DRIVERS})")
    prompts = np.asarray(prompts, np.int32)
    b = prompts.shape[0]
    if max_len is None:
        max_len = prompts.shape[1] + gen
    cache = model.init_cache(b, max_len)
    if src_tokens is not None:
        if model.populate_memory is None:
            raise ValueError(
                f"family {model.cfg.family!r} takes token-only input "
                f"(no encoder memory); src_tokens is encdec-only"
            )
        src = np.asarray(src_tokens, np.int32)
        if src.ndim == 1:
            src = np.broadcast_to(src, (b, src.shape[0]))
        cap = model.cfg.frontend_len
        if src.shape[1] > cap:
            raise ValueError(
                f"src_tokens needs {src.shape[1]} encoder positions, the "
                f"cache's memory rows hold {cap}"
            )
        cache = model.populate_memory(params, cache, jnp.asarray(src))
    sampling = model_common.make_sampling(temperature, top_k)
    keys = model_common.slot_keys(seed, b)
    if driver == "python":
        if decode is None:
            decode = _decode_fn(model)
        return _python_loop(decode, params, cache, prompts, gen,
                            sampling, keys)
    return _fused_generate(model, params, cache, prompts, gen,
                           sampling, keys)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

class Request(NamedTuple):
    uid: int
    prompt: np.ndarray            # (plen,) int32
    gen: int
    src_tokens: Optional[np.ndarray] = None   # (slen,) int32 encoder input
    key: Optional[np.ndarray] = None          # (2,) uint32 sampling base key


class Completion(NamedTuple):
    uid: int
    tokens: np.ndarray            # (gen,) int32 generated tokens
    prompt_logits: np.ndarray     # (V,) fp32 logits after the prompt


def _zero_slot(leaf, i):
    """Zero one slot's rows of a cache leaf.  Convention (every family):
    the only 1-D cache leaves are the per-slot ``pos``/``mem_len``
    counters; everything else stacks (L, B, ...) with the slot axis second.
    Memory-awareness: zeroing an encdec slot leaves ``mem_len`` at 0 —
    every cross-attention memory row masked — which decodes exactly as the
    zeroed ``mem_k``/``mem_v`` rows would (zero output), so a token-only
    request admitted after an encdec occupant can never see stale memory.
    ``admit_memory`` then overwrites the memory rows + ``mem_len`` for
    requests that DO carry encoder input."""
    if leaf.ndim == 1:
        return leaf.at[i].set(0)
    return leaf.at[:, i].set(jnp.zeros_like(leaf[:, i]))


@functools.partial(jax.jit, donate_argnums=(0,))
def _admit_slot(state, i, token_row, prompt_len, total_len, key_row):
    """Reset slot ``i`` for a new request — cache rows zeroed, prompt
    written, per-slot lengths + sampling key set — as ONE donated dispatch
    (a leaf-by-leaf host-side reset costs a dispatch per cache leaf per
    admission, which dominates small-model chunks)."""
    return model_common.GenState(
        cache=jax.tree.map(lambda leaf: _zero_slot(leaf, i), state.cache),
        tokens=state.tokens.at[i].set(token_row),
        prompt_len=state.prompt_len.at[i].set(prompt_len),
        total_len=state.total_len.at[i].set(total_len),
        active=state.active.at[i].set(True),
        prompt_logits=state.prompt_logits.at[i].set(0.0),
        rng=state.rng.at[i].set(key_row),
    )


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _admit_slot_mem(admit_memory, state, params, i, token_row, prompt_len,
                    total_len, key_row, src_row):
    """Admission for a request carrying encoder input: the slot reset PLUS
    one encode — ``admit_memory`` runs the model's encoder on ``src_row``
    and writes the projected cross-attention K/V into that slot's
    ``mem_k``/``mem_v`` rows (and its ``mem_len``) — all inside the same
    donated dispatch.  Compiles once per distinct source length (the encode
    is shape-specialized, like every other jitted entry point)."""
    cache = jax.tree.map(lambda leaf: _zero_slot(leaf, i), state.cache)
    cache = admit_memory(params, cache, i, src_row)
    return model_common.GenState(
        cache=cache,
        tokens=state.tokens.at[i].set(token_row),
        prompt_len=state.prompt_len.at[i].set(prompt_len),
        total_len=state.total_len.at[i].set(total_len),
        active=state.active.at[i].set(True),
        prompt_logits=state.prompt_logits.at[i].set(0.0),
        rng=state.rng.at[i].set(key_row),
    )


class Engine:
    """Slot-based continuous-batching engine over the fused decode driver.

    ``slots`` cache rows are stepped together in fused chunks of
    ``chunk_steps`` tokens; between chunks (the only points Python touches
    the loop) finished slots are harvested and queued requests admitted.
    Each admission resets exactly one slot — cache rows zeroed, prompt
    written, per-slot lengths set — so heterogeneous request streams keep
    every slot busy instead of padding to the longest request.

    Decode is DETERMINISTIC in length: a request admitted with prompt
    ``plen`` and budget ``gen`` retires after exactly ``plen + gen - 1``
    fused steps (sampling changes WHICH tokens come out, never how many).
    The engine therefore schedules entirely with host-side arithmetic — no
    device→host readback at chunk boundaries; the device is touched between
    chunks only to harvest a finished slot's rows (once per request) and to
    admit its successor.

    Encoder-decoder requests ride slots like any other: ``submit`` takes
    the request's source tokens, admission runs ONE jitted encode
    (``_admit_slot_mem`` — the slot reset and the encode share a donated
    dispatch) and writes the projected cross-attention K/V into that slot's
    ``mem_k``/``mem_v`` rows; ``mem_len`` masks the unused tail rows.
    Token-only admissions zero the memory rows and pin ``mem_len`` to 0, so
    a recycled slot never leaks a previous occupant's memory.

    Sampling: ``temperature``/``top_k`` apply engine-wide; each request
    samples under its own base key (derived from ``seed`` — per-request
    override via ``submit(..., seed=)``), advanced by slot-local progress
    only, so staggered == isolated holds under stochastic sampling too.

    Limits: MoE serves, but staggered == isolated is not promised there
    (expert capacity couples batch rows; see ``mlp.moe_apply``).
    """

    def __init__(self, model, params, slots: int = 4, max_len: int = 128,
                 chunk_steps: int = 8, temperature: float = 0.0,
                 top_k: Optional[int] = None, seed: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk_steps = chunk_steps
        self.sampling = model_common.make_sampling(temperature, top_k)
        self.seed = seed
        self._step = model.decode_step        # raw step: scanned, not jitted
        self.queue: deque = deque()
        self._occupant: List[Optional[Request]] = [None] * slots
        self._remaining = [0] * slots         # fused steps until retirement
        self._uid = 0
        self.steps = 0            # fused steps run (occupancy accounting)
        self.slot_steps = 0       # steps × busy slots (useful work)
        self.state = model_common.gen_init(
            model.init_cache(slots, max_len),
            np.zeros((slots, max_len), np.int32),
            prompt_len=np.ones((slots,), np.int32),
            total_len=np.ones((slots,), np.int32),
            vocab=model.cfg.padded_vocab_size,
            active=np.zeros((slots,), bool),
        )

    @property
    def src_capacity(self) -> int:
        """Encoder positions a slot's memory rows hold (0 = token-only
        family)."""
        if self.model.admit_memory is None:
            return 0
        return self.model.cfg.frontend_len

    def submit(self, prompt, gen: int, src_tokens=None,
               seed: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1 or gen < 1:
            raise ValueError(
                f"request needs a non-empty prompt and gen >= 1, got "
                f"plen={len(prompt)} gen={gen}"
            )
        src = None
        if src_tokens is not None:
            if self.model.admit_memory is None:
                raise ValueError(
                    f"family {self.model.cfg.family!r} takes token-only "
                    f"requests (no encoder input); src_tokens is "
                    f"encdec-only"
                )
            src = np.asarray(src_tokens, np.int32).reshape(-1)
            if len(src) < 1:
                raise ValueError("src_tokens, when given, must be non-empty")
        need_dec = len(prompt) + gen
        need_enc = 0 if src is None else len(src)
        if need_dec > self.max_len or need_enc > self.src_capacity:
            raise ValueError(
                f"request needs {need_dec} decoder positions"
                + (f" and {need_enc} encoder positions" if src is not None
                   else "")
                + f", pool rows hold {self.max_len} decoder"
                + (f" and {self.src_capacity} encoder"
                   if src is not None else "")
                + " positions"
            )
        uid = self._uid
        self._uid += 1
        if seed is not None:
            # row-0 key of the request's own seed — the same key an
            # isolated ``generate(prompt[None], ..., seed=seed)`` run gives
            # its one row, so sampled staggered-vs-isolated parity holds
            # key-for-key
            key = model_common.slot_keys(seed, 1)[0]
        else:
            # default: hash the uid into the engine's stream (fold_in, not
            # seed+uid arithmetic — adjacent engine seeds or an explicit
            # per-request seed must not collide with another request's
            # default stream)
            key = jax.random.fold_in(
                model_common.slot_keys(self.seed, 1)[0], uid)
        self.queue.append(Request(uid, prompt, gen, src, np.asarray(key)))
        return uid

    # -- harvest + admission (between fused chunks) -------------------------

    def _harvest_slot(self, i: int) -> Completion:
        """Read a retired slot's generated rows (the once-per-request
        device read) and free it."""
        req = self._occupant[i]
        plen = len(req.prompt)
        toks = np.asarray(self.state.tokens[i, plen:plen + req.gen])
        plog = np.asarray(self.state.prompt_logits[i])
        self._occupant[i] = None
        return Completion(req.uid, toks, plog)

    def _admit_one(self, i: int, req: Request) -> None:
        plen = len(req.prompt)
        row = np.zeros((self.max_len,), np.int32)
        row[:plen] = req.prompt
        if req.src_tokens is None:
            self.state = _admit_slot(
                self.state, jnp.int32(i), jnp.asarray(row),
                jnp.int32(plen), jnp.int32(plen + req.gen),
                jnp.asarray(req.key),
            )
        else:
            # encode-at-admission: the request's encoder memory is computed
            # here (one jitted encode, donated like the plain reset) and
            # written into THIS slot's mem rows — never zeroed away
            self.state = _admit_slot_mem(
                self.model.admit_memory, self.state, self.params,
                jnp.int32(i), jnp.asarray(row),
                jnp.int32(plen), jnp.int32(plen + req.gen),
                jnp.asarray(req.key), jnp.asarray(req.src_tokens),
            )
        self._occupant[i] = req
        self._remaining[i] = plen + req.gen - 1

    def _turnover(self) -> List[Completion]:
        """Harvest every retired slot; refill from the queue."""
        done = []
        for i in range(self.slots):
            if self._occupant[i] is not None and self._remaining[i] <= 0:
                done.append(self._harvest_slot(i))
            if self._occupant[i] is None and self.queue:
                self._admit_one(i, self.queue.popleft())
        return done

    # -- main loop ----------------------------------------------------------

    def step_chunk(self) -> List[Completion]:
        """Harvest/admit → one fused chunk.  Returns completions.

        The chunk is shortened when every busy slot retires sooner — the
        tail of a drained workload never scans frozen lockstep steps.  At
        most ``chunk_steps`` distinct scan lengths ever compile."""
        done = self._turnover()
        busy = [i for i in range(self.slots) if self._occupant[i] is not None]
        if not busy:
            return done
        n = min(self.chunk_steps, max(self._remaining[i] for i in busy))
        self.state = _run_steps(self._step, self.params, self.state, n,
                                self.sampling)
        self.steps += n
        for i in busy:
            self.slot_steps += min(self._remaining[i], n)
            self._remaining[i] -= n
        return done

    def run(self) -> List[Completion]:
        """Drain the queue; returns every completion (match by uid)."""
        out: List[Completion] = []
        while self.queue or any(r is not None for r in self._occupant):
            out.extend(self.step_chunk())
        return out


