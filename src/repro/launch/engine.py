"""Serving engine: fused on-device decode driver + continuous batching.

Two layers, both family-agnostic (they only touch the uniform
``decode_step(params, cache, tokens) -> (logits, cache)`` /
``init_cache(batch, max_len)`` Model surface):

``generate(model, params, prompts, gen, driver=...)``
    One uniform batch, two drivers:

    * ``python`` — the legacy oracle: one jitted ``decode_step`` per token,
      driven from Python.  Pays a host→device dispatch round-trip plus a
      host sync (the argmax readback) per token.
    * ``fused``  — the whole generation (prefill-by-stepping → sample →
      append → step) runs as ONE jitted ``lax.scan`` per phase
      (``models.common.gen_scan``), with the state donated between phases.
      TT cores stay closure constants of the scanned body exactly as in
      ``common.tt_scan`` — the device never waits on Python between tokens.

``Engine``
    Continuous batching on top of the fused driver: a slot-based cache
    pool with per-slot lengths.  Requests with heterogeneous prompt/gen
    lengths are admitted into finished slots between fused chunks, prefill
    is chunked across those boundaries (a freshly admitted slot consumes
    its prompt tokens while neighbours keep decoding), and finished slots
    are harvested and refilled — the pool stays at high occupancy instead
    of padded-batch lockstep.
"""

from __future__ import annotations

import functools
import time
from collections import deque
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as model_common

DRIVERS = ("fused", "python")


def _decode_fn(model):
    return jax.jit(model.decode_step, donate_argnums=(1,))


def _python_loop(decode, params, cache, prompts, gen):
    """Legacy one-jitted-step-per-token loop (the ``--driver python``
    oracle).  Prefills by stepping the decode cache through the prompt,
    then greedy-decodes ``gen`` tokens; each token pays a dispatch plus the
    argmax host sync."""
    b, prompt_len = prompts.shape
    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, cache = decode(params, cache, jnp.asarray(prompts[:, i:i+1]))
    jax.block_until_ready(logits)
    prefill_t = time.time() - t0
    prompt_logits = logits

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_t = time.time() - t0
    return {
        "prefill_t": prefill_t,
        "decode_t": decode_t,
        "gen": np.concatenate(out_tokens, axis=1),
        "prompt_logits": prompt_logits,
    }


@functools.partial(jax.jit, static_argnums=(0, 3), donate_argnums=(2,))
def _run_steps(decode_step, params, state, n_steps):
    """``n_steps`` fused decode steps, state donated across chunk calls so
    the cache pool is updated in place between Python-side admissions."""
    return model_common.gen_scan(decode_step, params, state, n_steps)


def _fused_generate(model, params, cache, prompts, gen):
    """Whole-generation fused driver: two scanned phases (prefill, decode)
    so the timing split matches the python loop's reporting boundaries."""
    decode = model.decode_step            # raw step: scanned, not re-jitted
    b, prompt_len = prompts.shape
    t_max = int(prompt_len + gen)
    tokens = np.zeros((b, t_max), np.int32)
    tokens[:, :prompt_len] = prompts
    state = model_common.gen_init(
        cache, tokens, prompt_len, t_max, model.cfg.padded_vocab_size
    )
    t0 = time.time()
    state = _run_steps(decode, params, state, prompt_len)
    state = jax.block_until_ready(state)
    prefill_t = time.time() - t0
    t0 = time.time()
    if gen > 1:
        state = _run_steps(decode, params, state, gen - 1)
        state = jax.block_until_ready(state)
    decode_t = time.time() - t0
    return {
        "prefill_t": prefill_t,
        "decode_t": decode_t,
        "gen": np.asarray(state.tokens[:, prompt_len:]),
        "prompt_logits": state.prompt_logits,
    }


def generate(model, params, prompts, gen: int, max_len: Optional[int] = None,
             driver: str = "fused", decode=None) -> dict:
    """One uniform-batch serving run; single source of truth for
    prefill-by-stepping + greedy decode + timing boundaries.

    Returns ``{prefill_t, decode_t, gen (B, gen) np.int32, prompt_logits}``
    — identical contract (and, token for token, identical output) for both
    drivers.  ``decode`` lets python-driver callers share one jitted step
    across runs (the fused driver keys its compile cache on
    ``model.decode_step`` itself and needs no sharing).
    """
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r} (choose from {DRIVERS})")
    prompts = np.asarray(prompts, np.int32)
    if max_len is None:
        max_len = prompts.shape[1] + gen
    cache = model.init_cache(prompts.shape[0], max_len)
    if driver == "python":
        if decode is None:
            decode = _decode_fn(model)
        return _python_loop(decode, params, cache, prompts, gen)
    return _fused_generate(model, params, cache, prompts, gen)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

class Request(NamedTuple):
    uid: int
    prompt: np.ndarray            # (plen,) int32
    gen: int


class Completion(NamedTuple):
    uid: int
    tokens: np.ndarray            # (gen,) int32 generated tokens
    prompt_logits: np.ndarray     # (V,) fp32 logits after the prompt


def _zero_slot(leaf, i):
    """Zero one slot's rows of a cache leaf.  Convention (every family):
    the only 1-D cache leaf is the per-slot ``pos``; everything else stacks
    (L, B, ...) with the slot axis second."""
    if leaf.ndim == 1:
        return leaf.at[i].set(0)
    return leaf.at[:, i].set(jnp.zeros_like(leaf[:, i]))


@functools.partial(jax.jit, donate_argnums=(0,))
def _admit_slot(state, i, token_row, prompt_len, total_len):
    """Reset slot ``i`` for a new request — cache rows zeroed, prompt
    written, per-slot lengths set — as ONE donated dispatch (a leaf-by-leaf
    host-side reset costs a dispatch per cache leaf per admission, which
    dominates small-model chunks)."""
    return model_common.GenState(
        cache=jax.tree.map(lambda leaf: _zero_slot(leaf, i), state.cache),
        tokens=state.tokens.at[i].set(token_row),
        prompt_len=state.prompt_len.at[i].set(prompt_len),
        total_len=state.total_len.at[i].set(total_len),
        active=state.active.at[i].set(True),
        prompt_logits=state.prompt_logits.at[i].set(0.0),
    )


class Engine:
    """Slot-based continuous-batching engine over the fused decode driver.

    ``slots`` cache rows are stepped together in fused chunks of
    ``chunk_steps`` tokens; between chunks (the only points Python touches
    the loop) finished slots are harvested and queued requests admitted.
    Each admission resets exactly one slot — cache rows zeroed, prompt
    written, per-slot lengths set — so heterogeneous request streams keep
    every slot busy instead of padding to the longest request.

    Greedy decode is DETERMINISTIC in length: a request admitted with
    prompt ``plen`` and budget ``gen`` retires after exactly
    ``plen + gen - 1`` fused steps.  The engine therefore schedules
    entirely with host-side arithmetic — no device→host readback at chunk
    boundaries; the device is touched between chunks only to harvest a
    finished slot's rows (once per request) and to admit its successor.

    Limits: requests are token-only — admission zeroes the slot's whole
    cache, so an encdec request's cross-attention memory (mem_k/mem_v via
    ``precompute_memory_cache``) cannot yet ride a slot; running encode at
    admission needs the request front-end (ROADMAP).  MoE serves, but
    staggered == isolated is not promised there (expert capacity couples
    batch rows; see ``mlp.moe_apply``).
    """

    def __init__(self, model, params, slots: int = 4, max_len: int = 128,
                 chunk_steps: int = 8):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk_steps = chunk_steps
        self._step = model.decode_step        # raw step: scanned, not jitted
        self.queue: deque = deque()
        self._occupant: List[Optional[Request]] = [None] * slots
        self._remaining = [0] * slots         # fused steps until retirement
        self._uid = 0
        self.steps = 0            # fused steps run (occupancy accounting)
        self.slot_steps = 0       # steps × busy slots (useful work)
        self.state = model_common.gen_init(
            model.init_cache(slots, max_len),
            np.zeros((slots, max_len), np.int32),
            prompt_len=np.ones((slots,), np.int32),
            total_len=np.ones((slots,), np.int32),
            vocab=model.cfg.padded_vocab_size,
            active=np.zeros((slots,), bool),
        )

    def submit(self, prompt, gen: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1 or gen < 1:
            raise ValueError(
                f"request needs a non-empty prompt and gen >= 1, got "
                f"plen={len(prompt)} gen={gen}"
            )
        if len(prompt) + gen > self.max_len:
            raise ValueError(
                f"request needs {len(prompt) + gen} positions, "
                f"pool rows hold {self.max_len}"
            )
        uid = self._uid
        self._uid += 1
        self.queue.append(Request(uid, prompt, gen))
        return uid

    # -- harvest + admission (between fused chunks) -------------------------

    def _harvest_slot(self, i: int) -> Completion:
        """Read a retired slot's generated rows (the once-per-request
        device read) and free it."""
        req = self._occupant[i]
        plen = len(req.prompt)
        toks = np.asarray(self.state.tokens[i, plen:plen + req.gen])
        plog = np.asarray(self.state.prompt_logits[i])
        self._occupant[i] = None
        return Completion(req.uid, toks, plog)

    def _admit_one(self, i: int, req: Request) -> None:
        plen = len(req.prompt)
        row = np.zeros((self.max_len,), np.int32)
        row[:plen] = req.prompt
        self.state = _admit_slot(
            self.state, jnp.int32(i), jnp.asarray(row),
            jnp.int32(plen), jnp.int32(plen + req.gen),
        )
        self._occupant[i] = req
        self._remaining[i] = plen + req.gen - 1

    def _turnover(self) -> List[Completion]:
        """Harvest every retired slot; refill from the queue."""
        done = []
        for i in range(self.slots):
            if self._occupant[i] is not None and self._remaining[i] <= 0:
                done.append(self._harvest_slot(i))
            if self._occupant[i] is None and self.queue:
                self._admit_one(i, self.queue.popleft())
        return done

    # -- main loop ----------------------------------------------------------

    def step_chunk(self) -> List[Completion]:
        """Harvest/admit → one fused chunk.  Returns completions.

        The chunk is shortened when every busy slot retires sooner — the
        tail of a drained workload never scans frozen lockstep steps.  At
        most ``chunk_steps`` distinct scan lengths ever compile."""
        done = self._turnover()
        busy = [i for i in range(self.slots) if self._occupant[i] is not None]
        if not busy:
            return done
        n = min(self.chunk_steps, max(self._remaining[i] for i in busy))
        self.state = _run_steps(self._step, self.params, self.state, n)
        self.steps += n
        for i in busy:
            self.slot_steps += min(self._remaining[i], n)
            self._remaining[i] -= n
        return done

    def run(self) -> List[Completion]:
        """Drain the queue; returns every completion (match by uid)."""
        out: List[Completion] = []
        while self.queue or any(r is not None for r in self._occupant):
            out.extend(self.step_chunk())
        return out


