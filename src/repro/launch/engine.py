"""Serving engine: fused on-device decode driver + continuous batching.

Two layers, both family-agnostic (they only touch the uniform
``decode_step(params, cache, tokens) -> (logits, cache)`` /
``init_cache(batch, max_len)`` Model surface):

``generate(model, params, prompts, gen, driver=...)``
    One uniform batch, two drivers:

    * ``python`` — the legacy oracle: one jitted ``decode_step`` per token,
      driven from Python.  Pays a host→device dispatch round-trip plus a
      host sync (the sample/argmax readback) per token.
    * ``fused``  — the whole generation (prefill-by-stepping → sample →
      append → step) runs as ONE jitted ``lax.scan`` per phase
      (``models.common.gen_scan``), with the state donated between phases.
      TT cores stay closure constants of the scanned body exactly as in
      ``common.tt_scan`` — the device never waits on Python between tokens.

    Sampling (greedy, or temperature/top-k under per-row PRNG streams) and
    encoder input for encdec families (``src_tokens`` → memory populated
    before the first decode step) are part of the shared contract — the
    two drivers stay token-for-token identical under both.

``Engine``
    Continuous batching on top of the fused driver: a slot-based cache
    pool with per-slot lengths, per-slot sampling params, and two
    admission modes (``admission="scan"`` — a device-resident request
    queue admitted from INSIDE the fused scan — and ``"boundary"`` — one
    donated host dispatch per admission between chunks).  See the Engine
    docstring for the full contract.

One level up, ``launch/router.py`` spreads requests over N Engine
replicas and ``launch/server.py`` puts an async HTTP front door (SSE
streaming, deadlines, backpressure) in front of the router.
"""

from __future__ import annotations

import functools
import itertools
import time
from collections import deque
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as model_common

DRIVERS = ("fused", "python")
ADMISSION_MODES = ("auto", "scan", "boundary")


def _decode_fn(model):
    return jax.jit(model.decode_step, donate_argnums=(1,))


def _python_loop(decode, params, cache, prompts, gen,
                 sampling=model_common.GREEDY, keys=None):
    """Legacy one-jitted-step-per-token loop (the ``--driver python``
    oracle).  Prefills by stepping the decode cache through the prompt,
    then decodes ``gen`` tokens — greedy, or sampled under the SAME
    per-row ``fold_in(keys[row], t)`` streams the fused driver uses, so
    the oracle stays token-for-token even under stochastic sampling.  Each
    token pays a dispatch plus the sample/argmax host sync."""
    b, prompt_len = prompts.shape
    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, cache = decode(params, cache, jnp.asarray(prompts[:, i:i+1]))
    jax.block_until_ready(logits)
    prefill_t = time.time() - t0
    prompt_logits = logits

    def pick(logits, t):
        if sampling.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        keys_t = jax.vmap(jax.random.fold_in)(
            keys, jnp.full((b,), t, jnp.int32))
        return model_common.sample_tokens(logits, keys_t, sampling)[:, None]

    tok = pick(logits, 0)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for t in range(1, gen):
        logits, cache = decode(params, cache, tok)
        tok = pick(logits, t)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    decode_t = time.time() - t0
    return {
        "prefill_t": prefill_t,
        "decode_t": decode_t,
        "gen": np.concatenate(out_tokens, axis=1),
        "prompt_logits": prompt_logits,
    }


@functools.partial(jax.jit, static_argnums=(0, 3, 4), donate_argnums=(2,))
def _run_steps(decode_step, params, state, n_steps,
               sampling=model_common.GREEDY):
    """``n_steps`` fused decode steps, state donated across chunk calls so
    the cache pool is updated in place between Python-side admissions."""
    return model_common.gen_scan(decode_step, params, state, n_steps,
                                 sampling)


def _fused_generate(model, params, cache, prompts, gen,
                    sampling=model_common.GREEDY, keys=None):
    """Whole-generation fused driver: two scanned phases (prefill, decode)
    so the timing split matches the python loop's reporting boundaries."""
    decode = model.decode_step            # raw step: scanned, not re-jitted
    b, prompt_len = prompts.shape
    t_max = int(prompt_len + gen)
    tokens = np.zeros((b, t_max), np.int32)
    tokens[:, :prompt_len] = prompts
    state = model_common.gen_init(
        cache, tokens, prompt_len, t_max, model.cfg.padded_vocab_size,
        rng=keys,
    )
    t0 = time.time()
    state = _run_steps(decode, params, state, prompt_len, sampling)
    state = jax.block_until_ready(state)
    prefill_t = time.time() - t0
    t0 = time.time()
    if gen > 1:
        state = _run_steps(decode, params, state, gen - 1, sampling)
        state = jax.block_until_ready(state)
    decode_t = time.time() - t0
    return {
        "prefill_t": prefill_t,
        "decode_t": decode_t,
        "gen": np.asarray(state.tokens[:, prompt_len:]),
        "prompt_logits": state.prompt_logits,
    }


def generate(model, params, prompts, gen: int, max_len: Optional[int] = None,
             driver: str = "fused", decode=None, src_tokens=None,
             temperature: float = 0.0, top_k: Optional[int] = None,
             seed: int = 0) -> dict:
    """One uniform-batch serving run; single source of truth for
    prefill-by-stepping + sampling + timing boundaries.

    Returns ``{prefill_t, decode_t, gen (B, gen) np.int32, prompt_logits}``
    — identical contract (and, token for token, identical output) for both
    drivers.  ``decode`` lets python-driver callers share one jitted step
    across runs (the fused driver keys its compile cache on
    ``model.decode_step`` itself and needs no sharing).

    ``src_tokens`` — optional encoder input for encoder-decoder families:
    (S_src,) shared across the batch or (B, S_src) per row; encoded once up
    front and written into the cache's cross-attention memory
    (``model.populate_memory``) before any decode step runs.

    ``temperature``/``top_k``/``seed`` — stochastic sampling.  Row ``r``
    samples under ``fold_in(PRNGKey(seed), r)``; ``temperature=0`` (the
    default) is greedy argmax, bit-identical to the pre-sampling driver.
    """
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r} (choose from {DRIVERS})")
    prompts = np.asarray(prompts, np.int32)
    b = prompts.shape[0]
    if max_len is None:
        max_len = prompts.shape[1] + gen
    cache = model.init_cache(b, max_len)
    if src_tokens is not None:
        if model.populate_memory is None:
            raise ValueError(
                f"family {model.cfg.family!r} takes token-only input "
                f"(no encoder memory); src_tokens is encdec-only"
            )
        src = np.asarray(src_tokens, np.int32)
        if src.ndim == 1:
            src = np.broadcast_to(src, (b, src.shape[0]))
        cap = model.cfg.frontend_len
        if src.shape[1] > cap:
            raise ValueError(
                f"src_tokens needs {src.shape[1]} encoder positions, the "
                f"cache's memory rows hold {cap}"
            )
        cache = model.populate_memory(params, cache, jnp.asarray(src))
    sampling = model_common.make_sampling(temperature, top_k)
    keys = model_common.slot_keys(seed, b)
    if driver == "python":
        if decode is None:
            decode = _decode_fn(model)
        return _python_loop(decode, params, cache, prompts, gen,
                            sampling, keys)
    return _fused_generate(model, params, cache, prompts, gen,
                           sampling, keys)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

class Request(NamedTuple):
    uid: int
    prompt: np.ndarray            # (plen,) int32
    gen: int
    src_tokens: Optional[np.ndarray] = None   # (slen,) int32 encoder input
    key: Optional[np.ndarray] = None          # (2,) uint32 sampling base key
    temp: float = 0.0             # per-request temperature (0 = greedy)
    topk: int = 0                 # per-request top-k (0 = no filter)


class InvalidRequest(ValueError):
    """A submission rejected at validation — bad shape, bad sampling
    params, out-of-range tokens, or capacity the pool cannot hold.  The
    request never consumed a queue slot (HTTP 400)."""


class Completion(NamedTuple):
    uid: int
    tokens: np.ndarray            # (gen,) int32 generated tokens
    prompt_logits: np.ndarray     # (V,) fp32 logits after the prompt
    bad: bool = False             # tripped the NaN/Inf logit guard — the
                                  # tokens are poisoned; quarantine them


@functools.partial(jax.jit, donate_argnums=(0,))
def _admit_slot(state, i, token_row, prompt_len, total_len, key_row,
                temp, topk):
    """Reset slot ``i`` for a new request — cache rows zeroed, prompt
    written, per-slot lengths + sampling key/params set — as ONE donated
    dispatch (a leaf-by-leaf host-side reset costs a dispatch per cache
    leaf per admission, which dominates small-model chunks)."""
    return state._replace(
        cache=jax.tree.map(
            lambda leaf: model_common.zero_slot_leaf(leaf, i), state.cache),
        tokens=state.tokens.at[i].set(token_row),
        prompt_len=state.prompt_len.at[i].set(prompt_len),
        total_len=state.total_len.at[i].set(total_len),
        active=state.active.at[i].set(True),
        prompt_logits=state.prompt_logits.at[i].set(0.0),
        rng=state.rng.at[i].set(key_row),
        temp=state.temp.at[i].set(temp),
        topk=state.topk.at[i].set(topk),
        bad=(None if state.bad is None
             else state.bad.at[i].set(False)),
    )


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _admit_slot_mem(admit_memory, state, params, i, token_row, prompt_len,
                    total_len, key_row, temp, topk, src_row):
    """Admission for a request carrying encoder input: the slot reset PLUS
    one encode — ``admit_memory`` runs the model's encoder on ``src_row``
    and writes the projected cross-attention K/V into that slot's
    ``mem_k``/``mem_v`` rows (and its ``mem_len``) — all inside the same
    donated dispatch.  Compiles once per distinct source length (the encode
    is shape-specialized, like every other jitted entry point)."""
    cache = jax.tree.map(
        lambda leaf: model_common.zero_slot_leaf(leaf, i), state.cache)
    cache = admit_memory(params, cache, i, src_row)
    return state._replace(
        cache=cache,
        tokens=state.tokens.at[i].set(token_row),
        prompt_len=state.prompt_len.at[i].set(prompt_len),
        total_len=state.total_len.at[i].set(total_len),
        active=state.active.at[i].set(True),
        prompt_logits=state.prompt_logits.at[i].set(0.0),
        rng=state.rng.at[i].set(key_row),
        temp=state.temp.at[i].set(temp),
        topk=state.topk.at[i].set(topk),
        bad=(None if state.bad is None
             else state.bad.at[i].set(False)),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def _deactivate_slot(state, i):
    """Cancel an in-flight slot: freeze it (active=False) without touching
    its buffers — the next admission zeroes them anyway.  A slot
    deactivated HERE (between chunks) never transitions inside a step, so
    the in-scan harvest never copies it to the done buffer."""
    return state._replace(active=state.active.at[i].set(False))


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill_scan(state, q_tokens, q_plen, q_tlen, q_rng, q_temp, q_topk,
                 q_size):
    """Chunk-boundary refill for scan admission: replace the device queue
    with the current pending window and reset the drained done buffer —
    one donated dispatch per chunk, independent of how many requests it
    carries (boundary admission pays one dispatch per REQUEST instead)."""
    queue = model_common.ScanQueue(
        tokens=q_tokens, prompt_len=q_plen, total_len=q_tlen, rng=q_rng,
        temp=q_temp, topk=q_topk,
        head=jnp.zeros((), jnp.int32), size=q_size,
    )
    return state._replace(
        queue=queue,
        done=state.done._replace(count=jnp.zeros((), jnp.int32)),
    )


class Engine:
    """Slot-based continuous-batching engine over the fused decode driver.

    ``slots`` cache rows are stepped together in fused chunks of up to
    ``chunk_steps`` tokens.  Each admission resets exactly one slot —
    cache rows zeroed, prompt written, per-slot lengths, PRNG key, and
    sampling params set — so heterogeneous request streams keep every slot
    busy instead of padding to the longest request.  Two admission modes:

    * ``admission="scan"`` (the default for token-only families) — a
      device-resident FIFO (``models.common.ScanQueue``) rides the scanned
      state; every step opens with an in-scan admission sweep, so a slot
      that retires mid-chunk is refilled on the NEXT step without ending
      the chunk.  Retiring slots are copied into a device-side done buffer
      (``DoneBuf``) before re-admission can overwrite their rows; the host
      drains it once per chunk.  The host refills the queue window with one
      donated dispatch per chunk.
    * ``admission="boundary"`` — the pre-scan behavior: harvest + one
      donated ``_admit_slot`` dispatch per admission between chunks.
      Encoder-decoder engines always use this mode (admission runs the
      encode on the host side — ``_admit_slot_mem``); ``admission="auto"``
      picks ``scan`` for token-only families and ``boundary`` for encdec.

    Decode is DETERMINISTIC in length: a request admitted with prompt
    ``plen`` and budget ``gen`` retires after exactly ``plen + gen - 1``
    fused steps (sampling changes WHICH tokens come out, never how many).
    The engine therefore schedules entirely with host-side arithmetic —
    under scan admission it mirrors the device's admission sweep step by
    step (same FIFO order, same lowest-free-slot placement) — so there is
    no device→host readback at chunk boundaries; the device is read once
    per request at harvest (plus opt-in ``peek_tokens`` reads for
    streaming callers).

    Sampling is PER-REQUEST: ``submit(..., temperature=, top_k=, seed=)``
    rides the slot as ``GenState.temp``/``topk``/``rng`` — engine-level
    ``temperature``/``top_k`` are only the defaults for requests that don't
    set their own.  Keys advance with slot-local progress only, so
    staggered == isolated holds token-for-token under any mix of per-slot
    params; ``temperature=0`` requests take the greedy argmax
    (token-identical to an isolated greedy run).

    Encoder-decoder requests ride slots like any other: ``submit`` takes
    the request's source tokens, admission runs ONE jitted encode
    (``_admit_slot_mem`` — the slot reset and the encode share a donated
    dispatch) and writes the projected cross-attention K/V into that slot's
    ``mem_k``/``mem_v`` rows; ``mem_len`` masks the unused tail rows.
    Token-only admissions zero the memory rows and pin ``mem_len`` to 0, so
    a recycled slot never leaks a previous occupant's memory.

    ``cancel(uid)`` abandons a request (pending → dropped; in-flight → its
    slot is frozen at the next boundary and freed for re-admission); the
    serving layer uses it for deadline expiry and client disconnects.

    Limits: MoE serves, but staggered == isolated is not promised there
    (expert capacity couples batch rows; see ``mlp.moe_apply``).
    """

    def __init__(self, model, params, slots: int = 4, max_len: int = 128,
                 chunk_steps: int = 8, temperature: float = 0.0,
                 top_k: Optional[int] = None, seed: int = 0,
                 admission: str = "auto", queue_cap: Optional[int] = None):
        if admission not in ADMISSION_MODES:
            raise ValueError(
                f"unknown admission mode {admission!r} "
                f"(choose from {ADMISSION_MODES})"
            )
        if admission == "auto":
            admission = "boundary" if model.admit_memory is not None \
                else "scan"
        if admission == "scan" and model.admit_memory is not None:
            raise ValueError(
                f"family {model.cfg.family!r} carries encoder input; "
                f"admission runs the encode on the host, so it must use "
                f"admission='boundary' (or 'auto')"
            )
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk_steps = chunk_steps
        self.admission = admission
        self.sampling = model_common.make_sampling(temperature, top_k)
        self.seed = seed
        self._step = model.decode_step        # raw step: scanned, not jitted
        self.queue: deque = deque()
        self._occupant: List[Optional[Request]] = [None] * slots
        self._remaining = [0] * slots         # fused steps until retirement
        self._uid = 0
        self.steps = 0            # fused steps run (occupancy accounting)
        self.slot_steps = 0       # steps × busy slots (useful work)
        # max admissions (and retirements) in one chunk is one per slot per
        # step — size the device queue window and done buffer to that bound
        self._queue_cap = (slots * chunk_steps if queue_cap is None
                           else queue_cap)
        vocab = model.cfg.padded_vocab_size
        scan_mode = admission == "scan"
        self.state = model_common.gen_init(
            model.init_cache(slots, max_len),
            np.zeros((slots, max_len), np.int32),
            prompt_len=np.ones((slots,), np.int32),
            total_len=np.ones((slots,), np.int32),
            vocab=vocab,
            active=np.zeros((slots,), bool),
            temp=np.zeros((slots,), np.float32),
            topk=np.zeros((slots,), np.int32),
            queue=(model_common.make_scan_queue(self._queue_cap, max_len)
                   if scan_mode else None),
            done=(model_common.make_done_buf(slots * chunk_steps, max_len,
                                             vocab)
                  if scan_mode else None),
            bad=np.zeros((slots,), bool),
        )

    @property
    def src_capacity(self) -> int:
        """Encoder positions a slot's memory rows hold (0 = token-only
        family)."""
        if self.model.admit_memory is None:
            return 0
        return self.model.cfg.frontend_len

    @property
    def busy_slots(self) -> int:
        """Slots currently occupied (host view; exact between chunks)."""
        return sum(1 for r in self._occupant if r is not None)

    @property
    def pending(self) -> int:
        """Requests queued but not yet admitted (host view)."""
        return len(self.queue)

    @property
    def outstanding(self) -> int:
        """Requests submitted but not yet completed: pending + in-flight.
        The router's least-outstanding admission metric."""
        return self.pending + self.busy_slots

    @property
    def occupancy(self) -> float:
        """Lifetime useful-work fraction: busy slot-steps / total
        slot-steps."""
        return self.slot_steps / max(self.steps * self.slots, 1)

    def _token_ids(self, x, what: str) -> np.ndarray:
        """Coerce one token-id field to a flat int32 array, rejecting
        garbage (non-numeric, non-integral, out-of-vocab) with a typed
        error instead of silently truncating or clamping downstream."""
        try:
            arr = np.asarray(x)
        except Exception as e:
            raise InvalidRequest(f"{what} is not array-like: {e}") from None
        if arr.dtype.kind == "f":
            if arr.size and not np.all(np.isfinite(arr) & (arr == np.floor(arr))):
                raise InvalidRequest(
                    f"{what} must be integer token ids, got non-integral "
                    f"floats")
        elif arr.dtype.kind not in "iu":
            raise InvalidRequest(
                f"{what} must be integer token ids, got dtype {arr.dtype}")
        arr = arr.reshape(-1).astype(np.int32)
        vocab = self.model.cfg.vocab_size
        if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= vocab):
            raise InvalidRequest(
                f"{what} token ids must be in [0, {vocab}), got range "
                f"[{int(arr.min())}, {int(arr.max())}]")
        return arr

    def validate(self, prompt, gen: int, src_tokens=None,
                 temperature: Optional[float] = None,
                 top_k: Optional[int] = None):
        """Normalize + validate a request WITHOUT queuing it — the fail-
        fast check the router/server front door runs before admission (a
        bad request must 400 before it consumes a queue slot).  Returns
        ``(prompt, src, sampling)`` ready for ``submit``; raises
        ``InvalidRequest`` (a ``ValueError``) on anything malformed —
        oversized shapes, non-integer or out-of-range token ids, bad
        sampling params — so callers can map it to a typed 400."""
        prompt = self._token_ids(prompt, "prompt")
        if not isinstance(gen, (int, np.integer)):
            raise InvalidRequest(
                f"gen must be an integer, got {type(gen).__name__}")
        if len(prompt) < 1 or gen < 1:
            raise InvalidRequest(
                f"request needs a non-empty prompt and gen >= 1, got "
                f"plen={len(prompt)} gen={gen}"
            )
        src = None
        if src_tokens is not None:
            if self.model.admit_memory is None:
                raise InvalidRequest(
                    f"family {self.model.cfg.family!r} takes token-only "
                    f"requests (no encoder input); src_tokens is "
                    f"encdec-only"
                )
            src = self._token_ids(src_tokens, "src_tokens")
            if len(src) < 1:
                raise InvalidRequest(
                    "src_tokens, when given, must be non-empty")
        need_dec = len(prompt) + gen
        need_enc = 0 if src is None else len(src)
        if need_dec > self.max_len or need_enc > self.src_capacity:
            raise InvalidRequest(
                f"request needs {need_dec} decoder positions"
                + (f" and {need_enc} encoder positions" if src is not None
                   else "")
                + f", pool rows hold {self.max_len} decoder"
                + (f" and {self.src_capacity} encoder"
                   if src is not None else "")
                + " positions"
            )
        s = model_common.make_sampling(
            self.sampling.temperature if temperature is None else temperature,
            self.sampling.top_k if top_k is None else top_k,
        )
        return prompt, src, s

    def submit(self, prompt, gen: int, src_tokens=None,
               seed: Optional[int] = None,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None) -> int:
        """Queue one request; returns its uid (completions match by uid).

        ``temperature``/``top_k`` override the engine-wide defaults FOR
        THIS REQUEST (validated here, served per-slot); ``seed`` gives the
        request its own sampling stream — the row-0 key of an isolated
        ``generate(..., seed=seed)`` run, so sampled staggered-vs-isolated
        parity holds key-for-key.
        """
        prompt, src, s = self.validate(prompt, gen, src_tokens,
                                       temperature, top_k)
        uid = self._uid
        self._uid += 1
        if seed is not None:
            # row-0 key of the request's own seed — the same key an
            # isolated ``generate(prompt[None], ..., seed=seed)`` run gives
            # its one row, so sampled staggered-vs-isolated parity holds
            # key-for-key
            key = model_common.slot_keys(seed, 1)[0]
        else:
            # default: hash the uid into the engine's stream (fold_in, not
            # seed+uid arithmetic — adjacent engine seeds or an explicit
            # per-request seed must not collide with another request's
            # default stream)
            key = jax.random.fold_in(
                model_common.slot_keys(self.seed, 1)[0], uid)
        self.queue.append(Request(
            uid, prompt, gen, src, np.asarray(key),
            temp=s.temperature, topk=0 if s.top_k is None else s.top_k,
        ))
        return uid

    def cancel(self, uid: int) -> bool:
        """Abandon a request.  Pending → removed from the queue; in-flight
        → its slot is deactivated (one small dispatch; effective at the
        current chunk boundary) and freed for re-admission.  Returns False
        when the uid is unknown or already completed.  A canceled request
        never produces a Completion."""
        for j, req in enumerate(self.queue):
            if req.uid == uid:
                del self.queue[j]
                return True
        for i in range(self.slots):
            occ = self._occupant[i]
            if occ is not None and occ.uid == uid:
                self.state = _deactivate_slot(self.state, jnp.int32(i))
                self._occupant[i] = None
                self._remaining[i] = 0
                return True
        return False

    def progress(self, uid: int) -> Optional[int]:
        """Generated tokens available so far for an in-flight request
        (host arithmetic only; exact between chunks).  None when the uid
        is not currently in a slot."""
        for i in range(self.slots):
            occ = self._occupant[i]
            if occ is not None and occ.uid == uid:
                return max(0, occ.gen - self._remaining[i])
        return None

    def peek_tokens(self, uid: int) -> Optional[np.ndarray]:
        """The generated-so-far tokens of an in-flight request (one device
        row read — the streaming front door's per-chunk delta source).
        Call between chunks only.  None when the uid is not in a slot."""
        for i in range(self.slots):
            occ = self._occupant[i]
            if occ is not None and occ.uid == uid:
                plen = len(occ.prompt)
                avail = max(0, occ.gen - self._remaining[i])
                return np.asarray(self.state.tokens[i, plen:plen + avail])
        return None

    # -- boundary admission (between fused chunks) --------------------------

    def _harvest_slot(self, i: int) -> Completion:
        """Read a retired slot's generated rows (the once-per-request
        device read) and free it."""
        req = self._occupant[i]
        plen = len(req.prompt)
        toks = np.asarray(self.state.tokens[i, plen:plen + req.gen])
        plog = np.asarray(self.state.prompt_logits[i])
        bad = bool(np.asarray(self.state.bad[i]))
        self._occupant[i] = None
        return Completion(req.uid, toks, plog, bad=bad)

    def _admit_one(self, i: int, req: Request) -> None:
        plen = len(req.prompt)
        row = np.zeros((self.max_len,), np.int32)
        row[:plen] = req.prompt
        args = (
            jnp.int32(i), jnp.asarray(row),
            jnp.int32(plen), jnp.int32(plen + req.gen),
            jnp.asarray(req.key),
            jnp.float32(req.temp), jnp.int32(req.topk),
        )
        if req.src_tokens is None:
            self.state = _admit_slot(self.state, *args)
        else:
            # encode-at-admission: the request's encoder memory is computed
            # here (one jitted encode, donated like the plain reset) and
            # written into THIS slot's mem rows — never zeroed away
            self.state = _admit_slot_mem(
                self.model.admit_memory, self.state, self.params,
                *args, jnp.asarray(req.src_tokens),
            )
        self._occupant[i] = req
        self._remaining[i] = plen + req.gen - 1

    def _turnover(self) -> List[Completion]:
        """Harvest every retired slot; refill from the queue."""
        done = []
        for i in range(self.slots):
            if self._occupant[i] is not None and self._remaining[i] <= 0:
                done.append(self._harvest_slot(i))
            if self._occupant[i] is None and self.queue:
                self._admit_one(i, self.queue.popleft())
        return done

    def _chunk_sampling(self, requests) -> model_common.Sampling:
        """Static sampling mode for one chunk: the per-slot sampler pays a
        full-vocab sort + categorical EVERY step, so a chunk whose
        requests are all greedy (temp == 0) takes the static greedy path
        instead — token-identical (the per-slot sampler reduces to the
        same argmax at temp 0), and the host knows the chunk's request
        set, so the choice costs nothing on device."""
        if any(r is not None and r.temp > 0.0 for r in requests):
            return model_common.PER_SLOT
        return model_common.GREEDY

    def _step_chunk_boundary(self) -> List[Completion]:
        """Harvest/admit → one fused chunk.  Returns completions.

        The chunk is shortened when every busy slot retires sooner — the
        tail of a drained workload never scans frozen lockstep steps.  At
        most ``chunk_steps`` distinct scan lengths ever compile."""
        done = self._turnover()
        busy = [i for i in range(self.slots) if self._occupant[i] is not None]
        if not busy:
            return done
        n = min(self.chunk_steps, max(self._remaining[i] for i in busy))
        self.state = _run_steps(self._step, self.params, self.state, n,
                                self._chunk_sampling(self._occupant))
        self.steps += n
        for i in busy:
            self.slot_steps += min(self._remaining[i], n)
            self._remaining[i] -= n
        return done

    # -- scan admission (device-resident queue) -----------------------------

    def _queue_arrays(self, upload: List[Request]):
        """Pack the pending window into the device-queue buffers."""
        qc = self._queue_cap
        qt = np.zeros((qc, self.max_len), np.int32)
        qp = np.ones((qc,), np.int32)
        ql = np.ones((qc,), np.int32)
        qr = np.zeros((qc, 2), np.uint32)
        qtemp = np.zeros((qc,), np.float32)
        qk = np.zeros((qc,), np.int32)
        for j, req in enumerate(upload):
            plen = len(req.prompt)
            qt[j, :plen] = req.prompt
            qp[j] = plen
            ql[j] = plen + req.gen
            qr[j] = req.key
            qtemp[j] = req.temp
            qk[j] = req.topk
        return (jnp.asarray(qt), jnp.asarray(qp), jnp.asarray(ql),
                jnp.asarray(qr), jnp.asarray(qtemp), jnp.asarray(qk),
                jnp.int32(len(upload)))

    def _step_chunk_scan(self) -> List[Completion]:
        """One fused chunk with in-scan admission.

        The host first MIRRORS the device's schedule for up to
        ``chunk_steps`` steps — the same per-step sweep order the scan
        body runs (admit free slots from the FIFO lowest-index-first,
        decrement actives, retire exhausted slots in slot order) — which
        yields the exact chunk length, the admission consumption, and the
        done-buffer row → request mapping, all without touching the
        device.  Then: one refill dispatch, one fused chunk, one done-
        buffer read."""
        upload = list(itertools.islice(self.queue, self._queue_cap))
        sampling = self._chunk_sampling(list(self._occupant) + upload)
        occ = list(self._occupant)
        rem = list(self._remaining)
        qi = 0
        retired: List[Request] = []
        steps = busy_steps = 0
        for _ in range(self.chunk_steps):
            if qi >= len(upload) and all(o is None for o in occ):
                break                      # nothing left this chunk
            for i in range(self.slots):    # device Phase A: admission sweep
                if occ[i] is None and qi < len(upload):
                    req = upload[qi]
                    qi += 1
                    occ[i] = req
                    rem[i] = len(req.prompt) + req.gen - 1
            busy = [i for i in range(self.slots) if occ[i] is not None]
            busy_steps += len(busy)
            steps += 1
            for i in busy:                 # device Phase B: one decode step
                rem[i] -= 1
            for i in range(self.slots):    # device Phase C: retire + harvest
                if occ[i] is not None and rem[i] <= 0:
                    retired.append(occ[i])
                    occ[i] = None
                    rem[i] = 0
        if steps == 0:
            return []
        self.state = _refill_scan(self.state, *self._queue_arrays(upload))
        self.state = _run_steps(self._step, self.params, self.state, steps,
                                sampling)
        for _ in range(qi):
            self.queue.popleft()
        self._occupant, self._remaining = occ, rem
        self.steps += steps
        self.slot_steps += busy_steps
        out: List[Completion] = []
        if retired:
            # drain the done buffer — the once-per-request device read; row
            # order is the host-mirrored retirement order
            dt = np.asarray(self.state.done.tokens[:len(retired)])
            dl = np.asarray(self.state.done.prompt_logits[:len(retired)])
            db = np.asarray(self.state.done.bad[:len(retired)])
            for j, req in enumerate(retired):
                plen = len(req.prompt)
                out.append(Completion(
                    req.uid, dt[j, plen:plen + req.gen].copy(), dl[j],
                    bad=bool(db[j])))
        return out

    # -- main loop ----------------------------------------------------------

    def step_chunk(self) -> List[Completion]:
        """Advance the pool by one fused chunk; returns completions."""
        if self.admission == "scan":
            return self._step_chunk_scan()
        return self._step_chunk_boundary()

    def run(self) -> List[Completion]:
        """Drain the queue; returns every completion (match by uid)."""
        out: List[Completion] = []
        while self.queue or any(r is not None for r in self._occupant):
            out.extend(self.step_chunk())
        return out
