"""Production mesh definitions (DESIGN.md §4).

Single pod: TPU v5e-256 as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16); the pod axis
is the slow DCI link whose traffic the paper's TT compression targets.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state); the dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing
jax so the placeholder devices exist.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat


def make_mesh(shape, axes) -> Mesh:
    """Auto-axis mesh across jax versions (shim: ``repro.compat``)."""
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = jax.device_count()
    dp = n // model_parallel
    return make_mesh((dp, model_parallel), ("data", "model"))


def data_axis_size(mesh: Mesh | None, axis: str = "data") -> int:
    """Number of devices along ``axis`` (1 when absent/no mesh) — the
    fan-out the batched compression scheduler round-robins buckets over."""
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return int(mesh.devices.shape[mesh.axis_names.index(axis)])


def batch_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Hardware model for the roofline (TPU v5e per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link (~45GB/s usable quoted; 50 per spec)
ICI_LINKS = 4                     # v5e: 4 ICI links per chip (2D torus x2 dirs)
DCI_BW = 25e9                     # inter-pod (data-center) per-host estimate
