"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen1.5-0.5b --reduced --steps 50 --batch 8 --seq 256

Wires together every substrate layer: config → model → data pipeline →
sharded train step → checkpoint manager → fault-tolerant loop (restart
policy + straggler monitor) → optional FedTTD cross-pod sync.
Full-size configs train on real pods; ``--reduced`` runs the same loop
with the family-reduced config on whatever devices exist (the CPU CI path
and the ~100M-example path).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import pipeline as data_pipeline
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh, batch_axes
from repro.models.registry import build
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.fault_tolerance import (
    RestartPolicy, StragglerMonitor, TrainingFailure,
)
from repro.train.steps import TrainState, make_train_step


def train(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.microbatch:
        cfg = dataclasses.replace(cfg, microbatch=args.microbatch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    model = build(cfg)

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh(model_parallel=args.model_parallel)
    shd.set_mesh_axis_sizes(mesh)
    baxes = batch_axes(mesh)

    optimizer = AdamW(
        learning_rate=cosine_schedule(args.lr, args.warmup, args.steps),
        weight_decay=0.1,
    )
    step_fn = make_train_step(model, optimizer, batch_axes=baxes,
                              microbatch=cfg.microbatch)
    data = data_pipeline.for_model(cfg, shape, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    monitor = StragglerMonitor()

    p_specs = shd.param_specs(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(args.seed))),
        cfg,
    )

    with mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        params = jax.device_put(params, shd.named(mesh, p_specs))
        state = TrainState(params=params, opt=optimizer.init(params))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        start_step = 0
        if ckpt is not None and args.resume:
            latest = ckpt.latest_step()
            if latest is not None:
                state, manifest = ckpt.restore(state)
                start_step = manifest["step"] + 1
                print(f"[train] resumed from step {manifest['step']}")

        losses = []
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {
                k: jnp.asarray(v) for k, v in data.batch_at(step).items()
            }
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            straggler = monitor.observe(dt)
            if step % args.log_every == 0:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.0f}ms"
                      + (" STRAGGLER" if straggler else ""), flush=True)
            if ckpt is not None and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step, state)
        if ckpt is not None:
            ckpt.save(args.steps - 1, state)
            ckpt.wait()

    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": len(losses)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    out = train(args)
    print(f"[train] done: {out}")


if __name__ == "__main__":
    main()
