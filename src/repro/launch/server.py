"""Async HTTP front door for the serving stack (stdlib asyncio only).

One process, one event loop, N replica worker threads underneath: the
server parses HTTP/1.1 itself (no third-party web framework — the
container ships none, and the surface is three endpoints), hands requests
to ``launch/router.py``, and bridges each request's worker-thread events
into asyncio with ``loop.call_soon_threadsafe`` — no blocked executor
thread per in-flight request.

Endpoints:

``POST /v1/generate``
    Body: ``{"prompt": [ids], "gen": n}`` plus optional ``src_tokens``
    (encdec), ``temperature``/``top_k``/``seed`` (per-request sampling),
    ``deadline_ms`` and ``"stream": true``.  Non-streaming replies are one
    JSON object (tokens + replica + timing); streaming replies are SSE
    (``text/event-stream``): ``data: {"tokens": [...]}`` per fused chunk,
    then ``event: done`` with the full result.  Error mapping — 400 bad
    request (fails BEFORE placement), 429 + ``Retry-After`` when every
    live replica is at its queue bound, 503 + ``Retry-After`` when no
    replica is live or the serving replica died mid-flight
    (``replica_lost`` — retryable, the request was never silently
    re-decoded), 500 when a request is quarantined for non-finite logits
    (``poisoned``), 504 when the per-request deadline expires (slot
    freed), ``event: error`` mid-stream.  ``Retry-After`` is derived from
    the live queue depth over the measured completion rate, not a
    constant.

``GET /healthz``  health probe: ``{"status": "ok"|"degraded"|"down",
"live_replicas": n, "queue_depth": outstanding}`` — 200 while at least
one replica is live (``degraded`` = some replicas down or restarting),
503 + ``Retry-After`` when none is.  ``GET /stats``  router/replica
counters (state, outstanding, busy slots, lifetime occupancy, restarts,
last error).

Client disconnects propagate: the handler watches the socket for EOF
while waiting on events and calls ``Router.cancel`` so an abandoned
request stops burning slot-steps at the next chunk boundary.

Run it with ``python -m repro.launch.serve --serve ...`` (see
docs/SERVING.md for the operator's view) or embed via ``Server`` /
``serve_in_thread`` (what tests and benchmarks/serve_load.py do).
"""

from __future__ import annotations

import asyncio
import json
import threading
import time
from typing import Optional

import numpy as np

from repro.launch.router import NoLiveReplicas, QueueFull, Router

_MAX_HEADER = 64 * 1024
_MAX_BODY = 16 * 1024 * 1024


def _json_bytes(obj) -> bytes:
    return json.dumps(obj).encode()


def _response(status: int, body: bytes, content_type: str = "application/json",
              extra: str = "") -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              405: "Method Not Allowed", 429: "Too Many Requests",
              500: "Internal Server Error", 503: "Service Unavailable",
              504: "Gateway Timeout"}.get(status, "OK")
    return (
        f"HTTP/1.1 {status} {reason}\r\n"
        f"Content-Type: {content_type}\r\n"
        f"Content-Length: {len(body)}\r\n"
        f"{extra}"
        f"Connection: close\r\n\r\n"
    ).encode() + body


def _sse(payload: dict, event: Optional[str] = None) -> bytes:
    head = f"event: {event}\n" if event else ""
    return (head + f"data: {json.dumps(payload)}\n\n").encode()


class Server:
    """Asyncio HTTP server over a Router.

    ``default_deadline`` (seconds) applies to requests that don't carry
    their own ``deadline_ms``; ``None`` means no server-imposed deadline.
    ``port=0`` binds an ephemeral port (tests); the bound port is
    ``self.port`` after ``start()``.
    """

    def __init__(self, router: Router, host: str = "127.0.0.1",
                 port: int = 0, default_deadline: Optional[float] = None):
        self.router = router
        self.host = host
        self.port = port
        self.default_deadline = default_deadline
        self._server: Optional[asyncio.base_events.Server] = None

    async def start(self) -> "Server":
        self.router.start()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # -- request plumbing ---------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                head = await reader.readuntil(b"\r\n\r\n")
            except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
                return
            if len(head) > _MAX_HEADER:
                writer.write(_response(400, _json_bytes(
                    {"error": "headers too large"})))
                return
            lines = head.decode("latin-1").split("\r\n")
            try:
                method, path, _ = lines[0].split(" ", 2)
            except ValueError:
                writer.write(_response(400, _json_bytes(
                    {"error": "malformed request line"})))
                return
            headers = {}
            for ln in lines[1:]:
                if ":" in ln:
                    k, v = ln.split(":", 1)
                    headers[k.strip().lower()] = v.strip()
            body = b""
            clen = int(headers.get("content-length", "0") or "0")
            if clen:
                if clen > _MAX_BODY:
                    writer.write(_response(400, _json_bytes(
                        {"error": "body too large"})))
                    return
                body = await reader.readexactly(clen)
            await self._dispatch(method, path, body, reader, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                await writer.drain()
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, asyncio.CancelledError):
                pass

    def _retry_after_header(self) -> str:
        return f"Retry-After: {self.router.retry_after()}\r\n"

    async def _dispatch(self, method, path, body, reader, writer) -> None:
        if method == "GET" and path == "/healthz":
            st = self.router.stats()
            live = st["live_replicas"]
            depth = sum(r["outstanding"] for r in st["replicas"])
            if live == len(st["replicas"]):
                status = "ok"
            elif live > 0:
                status = "degraded"    # some replicas dead/restarting
            else:
                status = "down"        # load balancer should drain us
            body_obj = {"status": status, "live_replicas": live,
                        "queue_depth": depth}
            if live > 0:
                writer.write(_response(200, _json_bytes(body_obj)))
            else:
                writer.write(_response(503, _json_bytes(body_obj),
                                       extra=self._retry_after_header()))
            return
        if method == "GET" and path == "/stats":
            writer.write(_response(200, _json_bytes(self.router.stats())))
            return
        if path != "/v1/generate":
            writer.write(_response(404, _json_bytes({"error": "not found"})))
            return
        if method != "POST":
            writer.write(_response(405, _json_bytes(
                {"error": "POST required"})))
            return
        await self._generate(body, reader, writer)

    async def _generate(self, body, reader, writer) -> None:
        t_start = time.monotonic()
        try:
            req = json.loads(body.decode())
            prompt = np.asarray(req["prompt"], np.int32)
            gen = int(req["gen"])
            src = req.get("src_tokens")
            if src is not None:
                src = np.asarray(src, np.int32)
            temperature = req.get("temperature")
            top_k = req.get("top_k")
            seed = req.get("seed")
            stream = bool(req.get("stream", False))
            deadline = self.default_deadline
            if req.get("deadline_ms") is not None:
                deadline = float(req["deadline_ms"]) / 1e3
        except (ValueError, KeyError, TypeError, json.JSONDecodeError) as e:
            writer.write(_response(400, _json_bytes(
                {"error": f"bad request: {e}"})))
            return

        loop = asyncio.get_running_loop()
        events: asyncio.Queue = asyncio.Queue()
        try:
            ticket = self.router.submit(
                prompt, gen, src_tokens=src, seed=seed,
                temperature=temperature, top_k=top_k,
                deadline=deadline, stream=stream)
        except QueueFull as e:
            writer.write(_response(429, _json_bytes({"error": str(e)}),
                                   extra=self._retry_after_header()))
            return
        except NoLiveReplicas as e:
            writer.write(_response(503, _json_bytes(
                {"error": str(e), "retryable": True}),
                extra=self._retry_after_header()))
            return
        except ValueError as e:
            writer.write(_response(400, _json_bytes({"error": str(e)})))
            return
        # bridge worker-thread events into this loop; watch the socket for
        # client EOF so a disconnect cancels the request
        def _bridge(ev):
            try:
                loop.call_soon_threadsafe(events.put_nowait, ev)
            except RuntimeError:
                pass     # loop already closed (server stopping mid-request)

        ticket.attach(_bridge)
        eof = asyncio.ensure_future(reader.read(1))
        try:
            if stream:
                await self._stream_response(ticket, events, eof, writer,
                                            t_start)
            else:
                await self._block_response(ticket, events, eof, writer,
                                           t_start)
        finally:
            eof.cancel()

    async def _next_event(self, ticket, events, eof):
        """One router event, or ``("disconnect", None)`` on client EOF."""
        getter = asyncio.ensure_future(events.get())
        done, _ = await asyncio.wait(
            {getter, eof}, return_when=asyncio.FIRST_COMPLETED)
        if getter in done:
            return getter.result()
        getter.cancel()
        self.router.cancel(ticket)
        return ("disconnect", None)

    @staticmethod
    def _done_payload(ticket, comp, t_start) -> dict:
        return {
            "rid": ticket.rid,
            "replica": ticket.replica,
            "tokens": np.asarray(comp.tokens).tolist(),
            "latency_ms": round((time.monotonic() - t_start) * 1e3, 3),
        }

    async def _block_response(self, ticket, events, eof, writer,
                              t_start) -> None:
        while True:
            kind, payload = await self._next_event(ticket, events, eof)
            if kind == "delta":
                continue
            if kind == "disconnect":
                return
            if kind == "done":
                writer.write(_response(200, _json_bytes(
                    self._done_payload(ticket, payload, t_start))))
            elif kind == "expired":
                writer.write(_response(504, _json_bytes(
                    {"error": "deadline expired", "rid": ticket.rid})))
            elif kind == "cancelled":
                writer.write(_response(500, _json_bytes(
                    {"error": "cancelled", "rid": ticket.rid})))
            elif kind == "replica_lost":
                # retryable: at-most-once delivery means the request was
                # NOT re-decoded — the client decides whether to resend
                writer.write(_response(503, _json_bytes(
                    {"error": str(payload), "rid": ticket.rid,
                     "retryable": True}),
                    extra=self._retry_after_header()))
            elif kind == "poisoned":
                writer.write(_response(500, _json_bytes(
                    {"error": str(payload), "rid": ticket.rid,
                     "kind": "poisoned"})))
            else:
                writer.write(_response(500, _json_bytes(
                    {"error": str(payload), "rid": ticket.rid})))
            return

    async def _stream_response(self, ticket, events, eof, writer,
                               t_start) -> None:
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n"
        )
        await writer.drain()
        while True:
            kind, payload = await self._next_event(ticket, events, eof)
            if kind == "disconnect":
                return
            try:
                if kind == "delta":
                    writer.write(_sse(
                        {"tokens": np.asarray(payload).tolist()}))
                    await writer.drain()
                    continue
                if kind == "done":
                    writer.write(_sse(
                        self._done_payload(ticket, payload, t_start),
                        event="done"))
                elif kind == "expired":
                    writer.write(_sse({"error": "deadline expired"},
                                      event="error"))
                else:
                    # replica_lost / poisoned / error — the SSE channel has
                    # one error shape; ``kind`` tells the client which
                    writer.write(_sse({"error": str(payload or kind),
                                       "kind": kind,
                                       "retryable": kind == "replica_lost"},
                                      event="error"))
                await writer.drain()
            except ConnectionError:
                self.router.cancel(ticket)
            return


def serve_in_thread(router: Router, host: str = "127.0.0.1", port: int = 0,
                    default_deadline: Optional[float] = None):
    """Run a Server on its own event loop in a daemon thread; returns the
    started Server (``server.port`` is bound).  Call the returned
    ``shutdown()`` to stop the loop — the embedding entry point for tests
    and benchmarks/serve_load.py."""
    server = Server(router, host, port, default_deadline)
    loop = asyncio.new_event_loop()
    started = threading.Event()

    def _run():
        asyncio.set_event_loop(loop)
        loop.run_until_complete(server.start())
        started.set()
        loop.run_forever()
        loop.run_until_complete(server.stop())
        loop.close()

    thread = threading.Thread(target=_run, name="http-server", daemon=True)
    thread.start()
    if not started.wait(timeout=30.0):
        raise RuntimeError("HTTP server failed to start within 30s")

    def shutdown():
        async def _drain():
            # stop accepting, then cancel in-flight handlers so the loop
            # winds down clean (no destroyed-but-pending tasks)
            await server.stop()
            cur = asyncio.current_task()
            tasks = [t for t in asyncio.all_tasks() if t is not cur]
            for t in tasks:
                t.cancel()
            await asyncio.gather(*tasks, return_exceptions=True)

        try:
            asyncio.run_coroutine_threadsafe(_drain(), loop).result(
                timeout=30.0)
        except Exception:
            pass
        loop.call_soon_threadsafe(loop.stop)
        thread.join(timeout=30.0)
        router.close()

    return server, shutdown


def run_server(router: Router, host: str = "127.0.0.1", port: int = 8000,
               default_deadline: Optional[float] = None) -> None:
    """Blocking entry point for the CLI: serve until interrupted."""
    async def _main():
        server = Server(router, host, port, default_deadline)
        await server.start()
        print(f"serving on http://{server.host}:{server.port}  "
              f"(POST /v1/generate, GET /healthz, GET /stats)")
        try:
            await asyncio.Event().wait()
        finally:
            await server.stop()
            router.close()

    try:
        asyncio.run(_main())
    except KeyboardInterrupt:
        pass
