"""input_specs: ShapeDtypeStruct stand-ins for every (arch × shape) cell.

Nothing here allocates — params, batches, and caches are eval_shape'd.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.registry import Model, build
from repro.optim.adamw import AdamW


class CellSpecs(NamedTuple):
    kind: str
    params: Any                      # ShapeDtypeStruct pytree
    batch: Any                       # train/prefill batch spec (or tokens)
    cache: Any                       # decode cache spec (decode only)
    opt: Any                         # optimizer state spec (train only)


def params_shape(model: Model, seed: int = 0):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(seed)))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                optimizer: AdamW | None = None) -> CellSpecs:
    model = build(cfg)
    p_shape = params_shape(model)
    if shape.kind == "train":
        batch = model.train_batch_spec(shape.global_batch, shape.seq_len)
        opt = None
        if optimizer is not None:
            opt = jax.eval_shape(optimizer.init, p_shape)
        return CellSpecs("train", p_shape, batch, None, opt)
    if shape.kind == "prefill":
        batch = model.prefill_batch_spec(shape.global_batch, shape.seq_len)
        return CellSpecs("prefill", p_shape, batch, None, None)
    if shape.kind == "decode":
        batch = model.decode_batch_spec(shape.global_batch)
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        return CellSpecs("decode", p_shape, batch, cache, None)
    raise ValueError(shape.kind)
