"""Multi-replica request router: load-aware admission over N Engines.

One Engine serves ``slots`` concurrent requests on one device (or mesh
slice).  Scaling past that means N engine REPLICAS — same model, same
params (shared host memory), independent cache pools — each driven by its
own worker thread.  JAX releases the GIL while XLA executes, so replica
chunks overlap on multicore hosts; on a single core they interleave but
stay correct.

The router owns four decisions the engine deliberately does not make:

* **Placement** — ``submit`` picks the replica with the fewest
  outstanding requests (pending + in-flight), breaking ties by lifetime
  occupancy (least-loaded wins) and then lowest index.  The rule is pure
  host arithmetic over counters the router itself maintains, so a seeded
  request trace maps to replicas deterministically — testable without
  ever starting the workers.  Only ``live`` replicas are placement
  candidates.
* **Backpressure** — each replica admits at most ``queue_depth``
  outstanding requests; when every live replica is full, ``submit``
  raises ``QueueFull`` IMMEDIATELY (the HTTP layer turns this into 429).
  A bounded queue is the contract: a request is either admitted, rejected
  now, or completed — never silently parked.
* **Lifecycle** — per-request deadlines (checked between fused chunks;
  an expired request is cancelled, its slot freed, and the ticket
  resolves to ``DeadlineExpired``) and cancellation (client disconnects
  propagate to ``Engine.cancel`` so abandoned requests stop burning
  slot-steps).
* **Supervision** — a supervisor thread watches every worker: each loop
  iteration refreshes the replica's heartbeat, so a dead thread (XLA
  error, injected fault) or a watchdog-stale heartbeat (slow chunk) is
  noticed within ``supervise_interval``.  A dead replica's tickets split
  at the at-most-once boundary: requests NOT yet admitted into a slot
  (mailbox or engine pending queue — zero tokens ever left the device)
  fail over to a live replica and complete normally; requests already
  admitted (tokens may have streamed) complete with a retryable
  ``replica_lost`` error — the router NEVER silently re-decodes a
  partially delivered request.  The dead replica then restarts
  single-flight — a fresh Engine (the old one's donated buffers are
  unknown mid-chunk) under ``RestartPolicy`` bounded exponential
  backoff.  A stale-but-alive worker is only marked ``suspect`` (no new
  placements; its thread cannot be killed safely) and recovers to
  ``live`` when its heartbeat resumes.

Results flow back through per-request ``Ticket``s: a thread-safe event
queue carrying ``("delta", tokens)`` chunks for streaming consumers and a
terminal ``("done", Completion)`` / ``("expired", None)`` /
``("cancelled", None)`` / ``("replica_lost", msg)`` / ``("poisoned",
msg)`` / ``("error", msg)``.  ``Ticket.result()`` is the blocking
convenience used by tests and the load benchmark; ``launch/server.py``
bridges the same queue into asyncio for SSE.
"""

from __future__ import annotations

import math
import queue
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from repro.launch.engine import Completion, Engine
from repro.runtime.fault_tolerance import RestartPolicy

# replica lifecycle states (stats()["replicas"][i]["state"])
LIVE = "live"                # worker running, placement candidate
SUSPECT = "suspect"          # heartbeat stale (slow chunk): no new
                             # placements, recovers when the heartbeat does
DEAD = "dead"                # worker thread exited (restarts exhausted or
                             # restart pending)
RESTARTING = "restarting"    # single-flight restart in progress


class QueueFull(RuntimeError):
    """Every live replica is at its ``queue_depth`` bound — retry later
    (HTTP 429 + ``Retry-After``)."""


class DeadlineExpired(RuntimeError):
    """The request's deadline passed before it finished; its slot was
    freed (HTTP 504)."""


class RequestCancelled(RuntimeError):
    """The request was cancelled (client disconnect / explicit cancel)."""


class ReplicaLost(RuntimeError):
    """The replica serving this request died mid-flight.  At-most-once
    token delivery: the request was NOT silently re-decoded (its tokens
    may already have streamed), so it is safe to retry (HTTP 503)."""


class NumericFault(RuntimeError):
    """The request's logits went non-finite (NaN/Inf).  It was
    quarantined and its slot freed; sibling slots are unaffected."""


class NoLiveReplicas(RuntimeError):
    """Every replica is dead or restarting — nothing can take the request
    (HTTP 503; ``/healthz`` reports ``down``)."""


class Ticket:
    """Handle for one routed request.

    ``events`` is a thread-safe queue of ``(kind, payload)`` tuples
    emitted by the replica worker: zero or more ``("delta", np.ndarray)``
    token chunks (streaming requests only), then exactly one terminal
    event — ``("done", Completion)``, ``("expired", None)``,
    ``("cancelled", None)``, ``("replica_lost", str)`` (retryable —
    at-most-once delivery forbids a silent re-decode), ``("poisoned",
    str)`` (NaN/Inf logits — the request was quarantined), or
    ``("error", str)``.
    """

    def __init__(self, rid: int, replica: int, stream: bool,
                 deadline: Optional[float]):
        self.rid = rid
        self.replica = replica            # current placement (failover moves it)
        self.stream = stream
        self.deadline = deadline          # absolute time.monotonic() bound
        self.events: "queue.Queue" = queue.Queue()
        self.cancel_event = threading.Event()
        self.done_event = threading.Event()
        self._emit_lock = threading.Lock()
        self._listener = None

    def attach(self, fn) -> None:
        """Route events to ``fn(event)`` (called from the replica worker
        thread) instead of the pull queue; events already queued are
        flushed to ``fn`` first, in order.  The HTTP server uses this to
        bridge into asyncio via ``loop.call_soon_threadsafe`` — one
        callback per event instead of one blocked executor thread per
        in-flight request."""
        with self._emit_lock:
            while True:
                try:
                    fn(self.events.get_nowait())
                except queue.Empty:
                    break
            self._listener = fn

    def _emit(self, kind: str, payload=None) -> None:
        with self._emit_lock:
            if self._listener is not None:
                self._listener((kind, payload))
            else:
                self.events.put((kind, payload))

    def result(self, timeout: Optional[float] = None) -> Completion:
        """Block until the terminal event; returns the Completion or
        raises ``DeadlineExpired`` / ``RequestCancelled`` / ``ReplicaLost``
        / ``NumericFault`` / ``RuntimeError``.  Streaming deltas drained on
        the way are discarded (streaming consumers read ``events``
        directly instead)."""
        end = None if timeout is None else time.monotonic() + timeout
        while True:
            left = None if end is None else max(0.0, end - time.monotonic())
            kind, payload = self.events.get(timeout=left)
            if kind == "delta":
                continue
            if kind == "done":
                return payload
            if kind == "expired":
                raise DeadlineExpired(f"request {self.rid} missed deadline")
            if kind == "cancelled":
                raise RequestCancelled(f"request {self.rid} cancelled")
            if kind == "replica_lost":
                raise ReplicaLost(f"request {self.rid}: {payload}")
            if kind == "poisoned":
                raise NumericFault(f"request {self.rid}: {payload}")
            raise RuntimeError(f"request {self.rid} failed: {payload}")


class _Replica:
    """One engine + its worker thread + the command mailbox + the
    supervision bookkeeping the router reads about it."""

    def __init__(self, index: int, engine: Engine):
        self.index = index
        self.engine = engine
        self.commands: "queue.Queue" = queue.Queue()
        self.outstanding = 0              # router-side counter (lock-guarded)
        self.thread: Optional[threading.Thread] = None
        self.state = LIVE
        self.heartbeat = time.monotonic() # refreshed every worker iteration
        self.chunks = 0                   # worked chunks (fault-hook clock)
        self.error: Optional[str] = None  # last worker/restart exception
        self.restarts = 0                 # lifetime restart count
        # rid -> [ticket, submit args, admitted-to-slot?, engine uid].
        # ``admitted`` is the at-most-once boundary: True means tokens may
        # already have streamed, so on replica death the ticket gets a
        # retryable replica_lost instead of a silent re-decode.
        self.inflight: dict = {}
        self.fault_hook: Optional[Callable[[int], None]] = None
        self.completed = 0                # lifetime completions
        self.busy_s = 0.0                 # lifetime seconds inside step_chunk


def _clone_engine(eng: Engine) -> Engine:
    """Default restart factory: a fresh Engine with the dead one's
    construction params (model/params are shared host memory — only the
    cache pool and queues are rebuilt)."""
    return Engine(
        eng.model, eng.params, slots=eng.slots, max_len=eng.max_len,
        chunk_steps=eng.chunk_steps,
        temperature=eng.sampling.temperature, top_k=eng.sampling.top_k,
        seed=eng.seed, admission=eng.admission, queue_cap=eng._queue_cap,
    )


class Router:
    """Load-aware, supervised front of N Engine replicas.

    ``submit`` never blocks: it places the request (least-outstanding →
    occupancy tiebreak → lowest index, live replicas only), bumps the
    chosen replica's outstanding counter, and mails the work to its
    worker.  All engine interaction — ``Engine.submit``, chunk stepping,
    cancellation, harvest — happens on that replica's worker thread, so
    engines need no locking.  ``start()`` spawns the workers plus a
    supervisor; placement itself needs no workers, which keeps the
    routing rule unit-testable as a pure function of the trace.

    ``watchdog_s`` — per-chunk heartbeat bound: a worker whose heartbeat
    goes stale by more than this while it has work is marked ``suspect``
    (no new placements) until the heartbeat resumes.  ``None`` (default)
    disables the watchdog; thread-death supervision is always on.

    ``restart_policy`` — bounded exponential backoff for dead-replica
    restarts (``RestartPolicy``; its injectable ``sleep`` keeps tests and
    the chaos lane fast).  ``engine_factory(dead_engine) -> Engine``
    builds the replacement engine (default: clone construction params).
    """

    def __init__(self, engines: List[Engine], queue_depth: int = 16,
                 watchdog_s: Optional[float] = None,
                 restart_policy: Optional[RestartPolicy] = None,
                 engine_factory: Optional[Callable[[Engine], Engine]] = None,
                 supervise_interval: float = 0.05):
        if not engines:
            raise ValueError("router needs at least one engine replica")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.replicas = [_Replica(i, e) for i, e in enumerate(engines)]
        self.queue_depth = queue_depth
        self.watchdog_s = watchdog_s
        self.restart_policy = restart_policy or RestartPolicy(
            max_restarts=3, backoff_s=0.5, max_backoff_s=10.0)
        self.supervise_interval = supervise_interval
        self._engine_factory = engine_factory or _clone_engine
        self._lock = threading.Lock()
        self._rid = 0
        self._stop = threading.Event()
        self._started = False
        self._supervisor: Optional[threading.Thread] = None

    # -- placement ----------------------------------------------------------

    def pick_replica(self) -> int:
        """The routing rule: fewest outstanding, then lowest lifetime
        occupancy, then lowest index — over LIVE replicas only.  Raises
        ``QueueFull`` when every live replica is at the bound and
        ``NoLiveReplicas`` when none is live at all."""
        with self._lock:
            live = [r for r in self.replicas if r.state == LIVE]
            if not live:
                raise NoLiveReplicas(
                    f"all {len(self.replicas)} replicas dead or restarting")
            free = [r for r in live if r.outstanding < self.queue_depth]
            if not free:
                raise QueueFull(
                    f"all {len(live)} live replicas at queue_depth="
                    f"{self.queue_depth}"
                )
            best = min(free, key=lambda r: (r.outstanding,
                                            r.engine.occupancy, r.index))
            return best.index

    def submit(self, prompt, gen: int, src_tokens=None,
               seed: Optional[int] = None,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               deadline: Optional[float] = None,
               stream: bool = False) -> Ticket:
        """Place one request; returns its Ticket immediately.

        ``deadline`` is seconds from now; expiry between chunks cancels
        the request and frees its slot.  ``stream=True`` makes the worker
        emit ``("delta", tokens)`` events after each fused chunk.
        Raises ``ValueError`` (``InvalidRequest``) on bad params
        (fail-fast, before placement), ``QueueFull`` when no live replica
        has room, and ``NoLiveReplicas`` when every replica is down.
        """
        # validate against replica 0 — replicas are homogeneous, and a bad
        # request must be rejected before it consumes a queue slot
        self.replicas[0].engine.validate(prompt, gen, src_tokens,
                                         temperature, top_k)
        abs_deadline = (None if deadline is None
                        else time.monotonic() + deadline)
        while True:
            idx = self.pick_replica()
            rep = self.replicas[idx]
            # counter bump + mailbox put are atomic with a state re-check:
            # a replica that died between pick and put must not swallow
            # the command (its mailbox is drained under this same lock)
            with self._lock:
                if rep.state != LIVE:
                    continue
                rid = self._rid
                self._rid += 1
                rep.outstanding += 1
                ticket = Ticket(rid, idx, stream, abs_deadline)
                rep.commands.put(("submit", ticket,
                                  (prompt, gen, src_tokens, seed,
                                   temperature, top_k)))
            return ticket

    def cancel(self, ticket: Ticket) -> None:
        """Request cancellation; the replica worker acts on it at the next
        chunk boundary (or before admission, if still queued)."""
        ticket.cancel_event.set()
        # wake the worker even when it is idle-blocking on its mailbox
        self.replicas[ticket.replica].commands.put(("nudge", None, None))

    # -- stats / health ------------------------------------------------------

    def live_replicas(self) -> int:
        """Replicas currently accepting placements (``live`` state)."""
        with self._lock:
            return sum(1 for r in self.replicas if r.state == LIVE)

    def retry_after(self) -> int:
        """Seconds a 429/503 client should wait, derived from the queue
        depth actually in front of it: least-loaded live backlog over the
        measured completion rate (lifetime completions / busy seconds).
        Clamped to [1, 30]; 5 when nothing is live (restart backoff
        territory), 1 before any rate is measured."""
        with self._lock:
            live = [r for r in self.replicas if r.state == LIVE]
            if not live:
                return 5
            backlog = min(r.outstanding for r in live)
            completed = sum(r.completed for r in live)
            busy = sum(r.busy_s for r in live)
        if completed < 1 or busy <= 0.0:
            return 1
        per_req = busy / completed            # mean busy-seconds per request
        return max(1, min(30, math.ceil(backlog * per_req)))

    def stats(self) -> dict:
        with self._lock:
            return {
                "queue_depth": self.queue_depth,
                "live_replicas": sum(1 for r in self.replicas
                                     if r.state == LIVE),
                "replicas": [
                    {
                        "index": r.index,
                        "state": r.state,
                        "outstanding": r.outstanding,
                        "busy_slots": r.engine.busy_slots,
                        "pending": r.engine.pending,
                        "steps": r.engine.steps,
                        "occupancy": round(r.engine.occupancy, 4),
                        "restarts": r.restarts,
                        "error": r.error,
                    }
                    for r in self.replicas
                ],
            }

    # -- lifecycle ----------------------------------------------------------

    def _spawn_worker(self, rep: _Replica) -> None:
        rep.thread = threading.Thread(
            target=self._worker_main, args=(rep,),
            name=f"replica-{rep.index}", daemon=True,
        )
        rep.thread.start()

    def start(self) -> "Router":
        if self._started:
            return self
        self._started = True
        for rep in self.replicas:
            self._spawn_worker(rep)
        self._supervisor = threading.Thread(
            target=self._supervise, name="router-supervisor", daemon=True)
        self._supervisor.start()
        return self

    def close(self) -> None:
        if not self._started:
            return
        self._stop.set()
        for rep in self.replicas:
            rep.commands.put(("nudge", None, None))
        for rep in self.replicas:
            t = rep.thread
            if t is None:
                continue
            if not t.is_alive() and rep.state in (LIVE, SUSPECT):
                # the worker crashed and nobody noticed yet (supervisor
                # raced with close): surface it instead of silently
                # "joining" a corpse
                with self._lock:
                    rep.state = DEAD
                    if rep.error is None:
                        rep.error = ("worker thread died without recording "
                                     "an exception")
            t.join(timeout=30.0)
        if self._supervisor is not None:
            self._supervisor.join(timeout=5.0)
            self._supervisor = None
        self._started = False
        self._stop.clear()

    def __enter__(self) -> "Router":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- supervision --------------------------------------------------------

    def _supervise(self) -> None:
        """Watch worker liveness (always) and heartbeat staleness (when
        ``watchdog_s`` is set).  Dead workers trigger the failover +
        restart path; stale-but-alive workers only flip to ``suspect`` —
        a Python thread stuck inside XLA cannot be killed safely, so the
        router just stops placing onto it until it breathes again."""
        while not self._stop.wait(self.supervise_interval):
            now = time.monotonic()
            for rep in self.replicas:
                if rep.state in (DEAD, RESTARTING):
                    continue
                t = rep.thread
                if t is not None and not t.is_alive():
                    self._on_replica_death(rep)
                    continue
                if self.watchdog_s is None:
                    continue
                stale = now - rep.heartbeat > self.watchdog_s
                with self._lock:
                    if rep.state == LIVE and stale and rep.outstanding > 0:
                        rep.state = SUSPECT
                    elif rep.state == SUSPECT and not stale:
                        rep.state = LIVE

    def _on_replica_death(self, rep: _Replica) -> None:
        """Failover for a dead worker.  Idempotent/single-flight: first
        caller (dying thread or supervisor) wins.  Splits the replica's
        tickets at the at-most-once boundary — never-admitted work moves
        to live replicas, admitted work fails retryably — then kicks off
        the bounded-backoff restart."""
        with self._lock:
            if rep.state in (DEAD, RESTARTING):
                return
            rep.state = DEAD
            if rep.error is None:
                rep.error = "worker thread died"
            entries = list(rep.inflight.values())
            rep.inflight.clear()
            # mailbox orphans never reached the worker at all — drained
            # under the router lock so submit() can't race a command into
            # a queue nobody will ever read
            mail = []
            while True:
                try:
                    cmd, ticket, args = rep.commands.get_nowait()
                except queue.Empty:
                    break
                if cmd == "submit":
                    mail.append((ticket, args))
            reason = rep.error
        lost = [(t, a) for t, a, admitted, _ in entries if admitted]
        pending = [(t, a) for t, a, admitted, _ in entries if not admitted]
        pending.extend(mail)
        for ticket, _ in lost:
            # tokens may already have streamed: complete with a retryable
            # typed error, never re-decode (at-most-once delivery)
            self._finish(rep, ticket, "replica_lost",
                         f"replica {rep.index} lost mid-flight ({reason})")
        for ticket, args in pending:
            self._failover(rep, ticket, args)
        self._restart_async(rep)

    def _failover(self, dead: _Replica, ticket: Ticket, args) -> None:
        """Move a never-admitted ticket to a live replica (its tokens are
        a pure function of its own request, so the re-run is exact); when
        nothing can take it, complete it retryably."""
        while True:
            try:
                idx = self.pick_replica()
            except (QueueFull, NoLiveReplicas) as e:
                self._finish(dead, ticket, "replica_lost",
                             f"replica {dead.index} died and no live "
                             f"replica could take over ({e})")
                return
            rep = self.replicas[idx]
            with self._lock:
                if rep.state != LIVE:
                    continue
                dead.outstanding -= 1
                rep.outstanding += 1
                ticket.replica = idx
                rep.commands.put(("submit", ticket, args))
            return

    def _restart_async(self, rep: _Replica) -> None:
        if self._stop.is_set() or not self._started:
            return
        with self._lock:
            if rep.state != DEAD:
                return
            rep.state = RESTARTING
        threading.Thread(
            target=self._restart, args=(rep,),
            name=f"replica-{rep.index}-restart", daemon=True,
        ).start()

    def _restart(self, rep: _Replica) -> None:
        """Single-flight replica restart under the bounded-backoff
        policy.  The engine is rebuilt from scratch — a worker that died
        mid-chunk leaves donated device buffers in an unknown state."""
        policy = self.restart_policy
        while not self._stop.is_set():
            rep.restarts += 1
            if rep.restarts > policy.max_restarts:
                with self._lock:
                    rep.state = DEAD
                return
            policy.sleep(policy.backoff(rep.restarts))
            if self._stop.is_set():
                break
            try:
                engine = self._engine_factory(rep.engine)
            except Exception as e:
                with self._lock:
                    rep.error = f"restart failed: {type(e).__name__}: {e}"
                continue
            with self._lock:
                rep.engine = engine
                rep.commands = queue.Queue()
                rep.inflight.clear()
                rep.chunks = 0
                rep.heartbeat = time.monotonic()
                rep.error = None
            # spawn BEFORE flipping LIVE: the supervisor skips RESTARTING
            # replicas, so it can't mistake the old dead thread for a
            # fresh-but-crashed worker during the handoff
            self._spawn_worker(rep)
            with self._lock:
                rep.state = LIVE
            return
        with self._lock:
            if rep.state == RESTARTING:
                rep.state = DEAD

    # -- worker -------------------------------------------------------------

    def _finish(self, rep: _Replica, ticket: Ticket, kind: str,
                payload=None) -> None:
        with self._lock:
            rep.outstanding -= 1
            rep.inflight.pop(ticket.rid, None)
        ticket._emit(kind, payload)
        ticket.done_event.set()

    def _worker_main(self, rep: _Replica) -> None:
        """Worker wrapper: record the fatal exception, then run the
        failover path from the dying thread itself (fast path — the
        supervisor is the backstop for anything that slips through)."""
        try:
            self._worker(rep)
        except BaseException as e:        # noqa: BLE001 — died means died
            with self._lock:
                rep.error = f"{type(e).__name__}: {e}"
            if not self._stop.is_set():
                self._on_replica_death(rep)

    def _worker(self, rep: _Replica) -> None:
        eng = rep.engine
        cmds = rep.commands
        live = {}          # engine uid -> Ticket
        sent = {}          # engine uid -> tokens already streamed
        while True:
            # drain the mailbox; block briefly when the engine is idle so
            # an idle replica doesn't spin
            block = not (eng.queue or any(o is not None
                                          for o in eng._occupant))
            if block and self._stop.is_set():
                break
            rep.heartbeat = time.monotonic()
            try:
                while True:
                    cmd, ticket, args = cmds.get(timeout=0.02 if block else 0)
                    block = False
                    if cmd == "nudge":
                        continue
                    # register BEFORE any processing: from here on a
                    # worker death hands the ticket to the failover path
                    # instead of stranding it
                    with self._lock:
                        rep.inflight[ticket.rid] = [ticket, args, False, None]
                    prompt, gen, src, seed, temp, topk = args
                    if ticket.cancel_event.is_set():
                        self._finish(rep, ticket, "cancelled")
                        continue
                    now = time.monotonic()
                    if ticket.deadline is not None and now > ticket.deadline:
                        self._finish(rep, ticket, "expired")
                        continue
                    try:
                        uid = eng.submit(prompt, gen, src_tokens=src,
                                         seed=seed, temperature=temp,
                                         top_k=topk)
                    except Exception as e:        # validated upstream, but
                        self._finish(rep, ticket, "error", str(e))
                        continue
                    with self._lock:
                        entry = rep.inflight.get(ticket.rid)
                        if entry is not None:
                            entry[3] = uid
                    live[uid] = ticket
                    sent[uid] = 0
            except queue.Empty:
                pass
            # deadline / cancellation sweep (between chunks — an engine
            # cancel here frees the slot for the next admission sweep)
            now = time.monotonic()
            for uid, ticket in list(live.items()):
                expired = (ticket.deadline is not None
                           and now > ticket.deadline)
                if ticket.cancel_event.is_set() or expired:
                    eng.cancel(uid)
                    self._finish(rep, ticket,
                                 "expired" if expired else "cancelled")
                    del live[uid]
                    sent.pop(uid, None)
            if not (eng.queue or any(o is not None for o in eng._occupant)):
                continue
            # chaos injection point: counts WORKED chunks only, so a
            # seeded FaultPlan hits a deterministic point in the schedule
            if rep.fault_hook is not None:
                rep.fault_hook(rep.chunks)
            # no blanket except here: a step_chunk failure leaves donated
            # device buffers in an unknown state, so the worker dies and
            # the supervisor fails over + restarts with a FRESH engine
            t0 = time.monotonic()
            done = eng.step_chunk()
            rep.busy_s += time.monotonic() - t0
            rep.chunks += 1
            rep.heartbeat = time.monotonic()
            finished = {c.uid for c in done}
            # flip the at-most-once flag BEFORE streaming: once a delta
            # may have left the process the ticket must never fail over
            with self._lock:
                for entry in rep.inflight.values():
                    if not entry[2] and entry[3] is not None:
                        uid = entry[3]
                        if uid in finished or eng.progress(uid) is not None:
                            entry[2] = True
            # stream per-chunk deltas for still-in-flight tickets (one
            # device row read per streaming ticket per chunk)
            for uid, ticket in live.items():
                if not ticket.stream or uid in finished:
                    continue
                avail = eng.progress(uid)
                if avail is not None and avail > sent[uid]:
                    toks = eng.peek_tokens(uid)
                    ticket._emit("delta", np.asarray(toks[sent[uid]:]))
                    sent[uid] = avail
            for c in done:
                ticket = live.pop(c.uid, None)
                n = sent.pop(c.uid, 0)
                if ticket is None:
                    continue              # cancelled earlier this loop
                if c.bad:
                    # numeric quarantine: the slot already came back with
                    # the normal retirement; only this request is failed
                    self._finish(rep, ticket, "poisoned",
                                 "non-finite logits (NaN/Inf) — request "
                                 "quarantined")
                    continue
                if ticket.stream and len(c.tokens) > n:
                    ticket._emit("delta", c.tokens[n:])
                rep.completed += 1
                self._finish(rep, ticket, "done", c)
