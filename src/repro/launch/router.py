"""Multi-replica request router: load-aware admission over N Engines.

One Engine serves ``slots`` concurrent requests on one device (or mesh
slice).  Scaling past that means N engine REPLICAS — same model, same
params (shared host memory), independent cache pools — each driven by its
own worker thread.  JAX releases the GIL while XLA executes, so replica
chunks overlap on multicore hosts; on a single core they interleave but
stay correct.

The router owns three decisions the engine deliberately does not make:

* **Placement** — ``submit`` picks the replica with the fewest
  outstanding requests (pending + in-flight), breaking ties by lifetime
  occupancy (least-loaded wins) and then lowest index.  The rule is pure
  host arithmetic over counters the router itself maintains, so a seeded
  request trace maps to replicas deterministically — testable without
  ever starting the workers.
* **Backpressure** — each replica admits at most ``queue_depth``
  outstanding requests; when every replica is full, ``submit`` raises
  ``QueueFull`` IMMEDIATELY (the HTTP layer turns this into 429).  A
  bounded queue is the contract: a request is either admitted, rejected
  now, or completed — never silently parked.
* **Lifecycle** — per-request deadlines (checked between fused chunks;
  an expired request is cancelled, its slot freed, and the ticket
  resolves to ``DeadlineExpired``) and cancellation (client disconnects
  propagate to ``Engine.cancel`` so abandoned requests stop burning
  slot-steps).

Results flow back through per-request ``Ticket``s: a thread-safe event
queue carrying ``("delta", tokens)`` chunks for streaming consumers and a
terminal ``("done", Completion)`` / ``("expired", None)`` /
``("cancelled", None)`` / ``("error", msg)``.  ``Ticket.result()`` is the
blocking convenience used by tests and the load benchmark;
``launch/server.py`` bridges the same queue into asyncio for SSE.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import List, Optional

import numpy as np

from repro.launch.engine import Completion, Engine


class QueueFull(RuntimeError):
    """Every replica is at its ``queue_depth`` bound — retry later (HTTP
    429)."""


class DeadlineExpired(RuntimeError):
    """The request's deadline passed before it finished; its slot was
    freed (HTTP 504)."""


class RequestCancelled(RuntimeError):
    """The request was cancelled (client disconnect / explicit cancel)."""


class Ticket:
    """Handle for one routed request.

    ``events`` is a thread-safe queue of ``(kind, payload)`` tuples
    emitted by the replica worker: zero or more ``("delta", np.ndarray)``
    token chunks (streaming requests only), then exactly one terminal
    event — ``("done", Completion)``, ``("expired", None)``,
    ``("cancelled", None)``, or ``("error", str)``.
    """

    def __init__(self, rid: int, replica: int, stream: bool,
                 deadline: Optional[float]):
        self.rid = rid
        self.replica = replica
        self.stream = stream
        self.deadline = deadline          # absolute time.monotonic() bound
        self.events: "queue.Queue" = queue.Queue()
        self.cancel_event = threading.Event()
        self.done_event = threading.Event()
        self._emit_lock = threading.Lock()
        self._listener = None

    def attach(self, fn) -> None:
        """Route events to ``fn(event)`` (called from the replica worker
        thread) instead of the pull queue; events already queued are
        flushed to ``fn`` first, in order.  The HTTP server uses this to
        bridge into asyncio via ``loop.call_soon_threadsafe`` — one
        callback per event instead of one blocked executor thread per
        in-flight request."""
        with self._emit_lock:
            while True:
                try:
                    fn(self.events.get_nowait())
                except queue.Empty:
                    break
            self._listener = fn

    def _emit(self, kind: str, payload=None) -> None:
        with self._emit_lock:
            if self._listener is not None:
                self._listener((kind, payload))
            else:
                self.events.put((kind, payload))

    def result(self, timeout: Optional[float] = None) -> Completion:
        """Block until the terminal event; returns the Completion or
        raises ``DeadlineExpired`` / ``RequestCancelled`` / ``RuntimeError``.
        Streaming deltas drained on the way are discarded (streaming
        consumers read ``events`` directly instead)."""
        end = None if timeout is None else time.monotonic() + timeout
        while True:
            left = None if end is None else max(0.0, end - time.monotonic())
            kind, payload = self.events.get(timeout=left)
            if kind == "delta":
                continue
            if kind == "done":
                return payload
            if kind == "expired":
                raise DeadlineExpired(f"request {self.rid} missed deadline")
            if kind == "cancelled":
                raise RequestCancelled(f"request {self.rid} cancelled")
            raise RuntimeError(f"request {self.rid} failed: {payload}")


class _Replica:
    """One engine + its worker thread + the command mailbox."""

    def __init__(self, index: int, engine: Engine):
        self.index = index
        self.engine = engine
        self.commands: "queue.Queue" = queue.Queue()
        self.outstanding = 0              # router-side counter (lock-guarded)
        self.thread: Optional[threading.Thread] = None


class Router:
    """Load-aware front of N Engine replicas.

    ``submit`` never blocks: it places the request (least-outstanding →
    occupancy tiebreak → lowest index), bumps the chosen replica's
    outstanding counter, and mails the work to its worker.  All engine
    interaction — ``Engine.submit``, chunk stepping, cancellation,
    harvest — happens on that replica's worker thread, so engines need no
    locking.  ``start()`` spawns the workers; placement itself needs no
    workers, which keeps the routing rule unit-testable as a pure
    function of the trace.
    """

    def __init__(self, engines: List[Engine], queue_depth: int = 16):
        if not engines:
            raise ValueError("router needs at least one engine replica")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.replicas = [_Replica(i, e) for i, e in enumerate(engines)]
        self.queue_depth = queue_depth
        self._lock = threading.Lock()
        self._rid = 0
        self._stop = threading.Event()
        self._started = False

    # -- placement ----------------------------------------------------------

    def pick_replica(self) -> int:
        """The routing rule: fewest outstanding, then lowest lifetime
        occupancy, then lowest index.  Raises ``QueueFull`` when every
        replica is at the bound."""
        with self._lock:
            free = [r for r in self.replicas
                    if r.outstanding < self.queue_depth]
            if not free:
                raise QueueFull(
                    f"all {len(self.replicas)} replicas at queue_depth="
                    f"{self.queue_depth}"
                )
            best = min(free, key=lambda r: (r.outstanding,
                                            r.engine.occupancy, r.index))
            return best.index

    def submit(self, prompt, gen: int, src_tokens=None,
               seed: Optional[int] = None,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               deadline: Optional[float] = None,
               stream: bool = False) -> Ticket:
        """Place one request; returns its Ticket immediately.

        ``deadline`` is seconds from now; expiry between chunks cancels
        the request and frees its slot.  ``stream=True`` makes the worker
        emit ``("delta", tokens)`` events after each fused chunk.
        Raises ``ValueError`` on bad params (fail-fast, before placement)
        and ``QueueFull`` when no replica has room.
        """
        # validate against replica 0 — replicas are homogeneous, and a bad
        # request must be rejected before it consumes a queue slot
        self.replicas[0].engine.validate(prompt, gen, src_tokens,
                                         temperature, top_k)
        idx = self.pick_replica()
        rep = self.replicas[idx]
        with self._lock:
            rid = self._rid
            self._rid += 1
            rep.outstanding += 1
        abs_deadline = (None if deadline is None
                        else time.monotonic() + deadline)
        ticket = Ticket(rid, idx, stream, abs_deadline)
        rep.commands.put(("submit", ticket,
                          (prompt, gen, src_tokens, seed, temperature,
                           top_k)))
        return ticket

    def cancel(self, ticket: Ticket) -> None:
        """Request cancellation; the replica worker acts on it at the next
        chunk boundary (or before admission, if still queued)."""
        ticket.cancel_event.set()
        # wake the worker even when it is idle-blocking on its mailbox
        self.replicas[ticket.replica].commands.put(("nudge", None, None))

    # -- stats --------------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {
                "queue_depth": self.queue_depth,
                "replicas": [
                    {
                        "index": r.index,
                        "outstanding": r.outstanding,
                        "busy_slots": r.engine.busy_slots,
                        "pending": r.engine.pending,
                        "steps": r.engine.steps,
                        "occupancy": round(r.engine.occupancy, 4),
                    }
                    for r in self.replicas
                ],
            }

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "Router":
        if self._started:
            return self
        self._started = True
        for rep in self.replicas:
            rep.thread = threading.Thread(
                target=self._worker, args=(rep,),
                name=f"replica-{rep.index}", daemon=True,
            )
            rep.thread.start()
        return self

    def close(self) -> None:
        if not self._started:
            return
        self._stop.set()
        for rep in self.replicas:
            rep.commands.put(("nudge", None, None))
        for rep in self.replicas:
            if rep.thread is not None:
                rep.thread.join(timeout=30.0)
        self._started = False
        self._stop.clear()

    def __enter__(self) -> "Router":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker -------------------------------------------------------------

    def _finish(self, rep: _Replica, ticket: Ticket, kind: str,
                payload=None) -> None:
        with self._lock:
            rep.outstanding -= 1
        ticket._emit(kind, payload)
        ticket.done_event.set()

    def _worker(self, rep: _Replica) -> None:
        eng = rep.engine
        live = {}          # engine uid -> Ticket
        sent = {}          # engine uid -> tokens already streamed
        while True:
            # drain the mailbox; block briefly when the engine is idle so
            # an idle replica doesn't spin
            block = not (eng.queue or any(o is not None
                                          for o in eng._occupant))
            if block and self._stop.is_set():
                break
            try:
                while True:
                    cmd, ticket, args = rep.commands.get(
                        timeout=0.02 if block else 0)
                    block = False
                    if cmd == "nudge":
                        continue
                    prompt, gen, src, seed, temp, topk = args
                    if ticket.cancel_event.is_set():
                        self._finish(rep, ticket, "cancelled")
                        continue
                    now = time.monotonic()
                    if ticket.deadline is not None and now > ticket.deadline:
                        self._finish(rep, ticket, "expired")
                        continue
                    try:
                        uid = eng.submit(prompt, gen, src_tokens=src,
                                         seed=seed, temperature=temp,
                                         top_k=topk)
                    except Exception as e:        # validated upstream, but
                        self._finish(rep, ticket, "error", str(e))
                        continue
                    live[uid] = ticket
                    sent[uid] = 0
            except queue.Empty:
                pass
            # deadline / cancellation sweep (between chunks — an engine
            # cancel here frees the slot for the next admission sweep)
            now = time.monotonic()
            for uid, ticket in list(live.items()):
                expired = (ticket.deadline is not None
                           and now > ticket.deadline)
                if ticket.cancel_event.is_set() or expired:
                    eng.cancel(uid)
                    self._finish(rep, ticket,
                                 "expired" if expired else "cancelled")
                    del live[uid]
                    sent.pop(uid, None)
            if not (eng.queue or any(o is not None for o in eng._occupant)):
                continue
            try:
                done = eng.step_chunk()
            except Exception as e:                # pragma: no cover
                for uid, ticket in live.items():
                    self._finish(rep, ticket, "error", str(e))
                live.clear()
                sent.clear()
                continue
            finished = {c.uid for c in done}
            # stream per-chunk deltas for still-in-flight tickets (one
            # device row read per streaming ticket per chunk)
            for uid, ticket in live.items():
                if not ticket.stream or uid in finished:
                    continue
                avail = eng.progress(uid)
                if avail is not None and avail > sent[uid]:
                    toks = eng.peek_tokens(uid)
                    ticket._emit("delta", np.asarray(toks[sent[uid]:]))
                    sent[uid] = avail
            for c in done:
                ticket = live.pop(c.uid, None)
                n = sent.pop(c.uid, 0)
                if ticket is None:
                    continue              # cancelled earlier this loop
                if ticket.stream and len(c.tokens) > n:
                    ticket._emit("delta", c.tokens[n:])
                self._finish(rep, ticket, "done", c)
