"""Serving driver: batched prefill + decode loop with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch gemma3-1b --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import pipeline as data_pipeline
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh, batch_axes
from repro.models.registry import build


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    shd.set_mesh_axis_sizes(mesh)

    rng = np.random.default_rng(args.seed)
    b = args.batch
    max_len = args.prompt_len + args.gen

    with mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        cache = model.init_cache(b, max_len)
        decode = jax.jit(model.decode_step, donate_argnums=(1,))

        prompts = rng.integers(
            0, cfg.vocab_size, size=(b, args.prompt_len), dtype=np.int32
        )
        # prefill by stepping the decode cache through the prompt (keeps one
        # compiled artifact; a chunked prefill kernel is the TPU fast path)
        t0 = time.time()
        logits = None
        for i in range(args.prompt_len):
            logits, cache = decode(params, cache, jnp.asarray(prompts[:, i:i+1]))
        prefill_t = time.time() - t0

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out_tokens = [np.asarray(tok)]
        t0 = time.time()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out_tokens.append(np.asarray(tok))
        jax.block_until_ready(logits)
        decode_t = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    tps = b * (args.gen - 1) / max(decode_t, 1e-9)
    print(f"[serve] prefill {args.prompt_len} toks in {prefill_t*1e3:.0f}ms; "
          f"decode {args.gen-1} steps @ {tps:.1f} tok/s "
          f"(batch={b})")
    print(f"[serve] sample generation: {gen[0][:16].tolist()}")
    return {"tok_per_s": tps, "generated": gen}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
