"""Serving driver: batched prefill + decode with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch gemma3-1b --reduced --batch 4 --prompt-len 32 --gen 16

Two modes share one model-setup path (``--weights``/``--tt-*`` work in
both):

* **batch mode** (default) — one uniform batch through
  ``launch/engine.generate``, timing + optional TT-vs-dense verify.
* **server mode** (``--serve``) — the production front door: N Engine
  replicas (``--replicas``/``--slots``/``--chunk-steps``) behind the
  load-aware router, fronted by the asyncio HTTP server
  (``--host``/``--port``; per-request deadlines via ``--deadline-ms``,
  backpressure via ``--queue-depth``).  See docs/SERVING.md for the
  operator's handbook.

Decode runs through ``launch/engine.py``: the default ``--driver fused``
executes the whole generation (prefill-by-stepping → sample → append →
step) as one jitted ``lax.scan`` per phase — no host→device dispatch
round-trip per token; ``--driver python`` keeps the legacy
one-jitted-step-per-token loop as the oracle.  Both main run and
``--verify`` oracle go through the same driver.

TT-native serving (``--weights tt``): the driver takes a TTCompressor
payload (compressed in-process from spectrally-decayed init weights, or
loaded from a ``--tt-checkpoint`` directory written by
``checkpoint.save_tt_payload``) and serves decode WITHOUT reconstructing
the dense matrices — layer matmuls contract activations straight against
the TT cores (``models.common.tt_native_params`` → ``core/tt_linear`` →
``kernels/tt_contract``).  ``--verify`` cross-checks the TT-native logits
against the reconstruct-then-serve path and reports resident weight bytes
for both modes.

Quantized TT serving (``--weights tt-int8``): same payload and serving
contract, but every TTLinear leaf stores int8 cores + symmetric scales
(``--quant-calib`` picks absmax or pXX percentile calibration) and the
fused kernels dequantize in-VMEM.  Logits move within the quantization
error, so ``--verify`` reports the measured next-token agreement — the
quantized gate is ≥99% agreement, not exact parity.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import engine as engine_mod
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build


def _dense_bytes(payload) -> int:
    """Dense resident bytes the payload WOULD occupy if reconstructed —
    from leaf metadata alone, so TT-native serve never materializes it."""
    from repro.core.compression import CompressedParam

    def is_cp(x):
        return isinstance(x, CompressedParam)

    return sum(
        int(np.prod(c.orig_shape)) * jnp.dtype(c.orig_dtype).itemsize
        for c in jax.tree.leaves(payload, is_leaf=is_cp)
    )


def _quant_of(weights: str):
    """``--weights tt-<fmt>`` → fmt (validated), plain ``tt``/``dense`` → None."""
    if weights.startswith("tt-"):
        from repro.core import quant_dtype
        fmt = weights[3:]
        quant_dtype(fmt)          # raise early on junk
        return fmt
    return None


def _teacher_forced_logits(model, params, prompts):
    """Per-position next-token logits, teacher-forced over the prompt via
    ``decode_step`` -> (b, S-1, V).  The quantized verify line measures
    agreement here: ``generate``'s prompt_logits is last-position only,
    far too few samples to state a percentage."""
    b, s = prompts.shape
    cache = model.init_cache(b, s)
    outs = []
    for t in range(s - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray(prompts[:, t:t + 1]))
        outs.append(np.asarray(logits, np.float32).reshape(b, -1))
    return np.stack(outs, 1)


def _tt_setup(params, args, cfg):
    """Compress (or load) the TT payload and build the TT-native params.

    Returns (params_tt, payload, report_line).  The dense oracle is NOT
    reconstructed here — only the verify pass pays for it (on by default;
    ``--no-verify`` serves with just cores + raw leaves resident).  Every
    family in the zoo carries TT-native leaves — the family's registered
    serving rules (``models.common.register_tt_serve_rules``) pick which
    weights serve from cores; the rest reconstruct as before.

    ``--weights tt-int8`` quantizes the built TT leaves in place
    (``quantize_tt_tree``) and the report line shows the byte ladder both
    ways: total resident bytes AND the TT-served-leaf bytes the contraction
    kernels actually stream (raw leaves — embeddings, norms — are identical
    across modes and dilute the total ratio).
    """
    from repro.core import (
        CompressionPolicy, TTCompressor, quant_dtype, quantize_tt_tree,
        spectral_decay_pytree, tt_leaf_bytes, tt_param_bytes,
    )
    from repro.models import common as model_common

    quant = _quant_of(getattr(args, "weights", "tt"))
    calib = getattr(args, "quant_calib", "absmax")
    comp = TTCompressor(CompressionPolicy(eps=args.tt_eps, min_size=8192))
    if args.tt_checkpoint:
        from repro.checkpoint.checkpoint import load_tt_payload
        payload, manifest = load_tt_payload(args.tt_checkpoint, like=params)
        ck_family = manifest.get("family")
        if ck_family is not None and ck_family != cfg.family:
            raise ValueError(
                f"TT checkpoint was compressed from family {ck_family!r}, "
                f"cannot serve arch family {cfg.family!r}"
            )
        ratio = None
    else:
        # random init has a flat spectrum (incompressible — the policy
        # correctly refuses); impose trained-like decay so the TT path
        # actually engages on a synthetic-weights driver run
        params = spectral_decay_pytree(params, alpha=args.tt_alpha)
        payload, report = comp.compress(params)
        ratio = report.ratio
        if getattr(args, "save_tt_checkpoint", None):
            from repro.checkpoint.checkpoint import save_tt_payload
            save_tt_payload(
                args.save_tt_checkpoint, payload,
                extra={"eps": args.tt_eps, "arch": cfg.name},
                family=cfg.family,
                quant=quant, quant_calib=calib,
            )
            print(f"[serve] TT payload saved to {args.save_tt_checkpoint}"
                  + (f" ({quant} cores)" if quant else ""))
    params_tt = model_common.tt_native_params(payload, family=cfg.family)
    dense_b = _dense_bytes(payload)
    tt_b = tt_param_bytes(params_tt)
    if quant is None:
        line = (f"weight bytes: dense {dense_b:,} -> tt-native {tt_b:,} "
                f"({dense_b / max(tt_b, 1):.2f}x resident reduction"
                + (f"; payload ratio {ratio:.2f}x params" if ratio else "")
                + ")")
        return params_tt, payload, line
    wide_leaf_b, dense_leaf_b = tt_leaf_bytes(params_tt)
    params_tt = quantize_tt_tree(
        params_tt, dtype=quant_dtype(quant), calib=calib
    )
    ttq_b = tt_param_bytes(params_tt)
    q_leaf_b, _ = tt_leaf_bytes(params_tt)
    line = (f"weight bytes: dense {dense_b:,} -> tt {tt_b:,} -> "
            f"tt-{quant} {ttq_b:,} ({dense_b / max(ttq_b, 1):.2f}x total); "
            f"TT-served leaves {wide_leaf_b:,} -> {q_leaf_b:,} "
            f"({wide_leaf_b / max(q_leaf_b, 1):.2f}x vs wide cores, "
            f"{dense_leaf_b / max(q_leaf_b, 1):.2f}x vs dense form)")
    return params_tt, payload, line


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    shd.set_mesh_axis_sizes(mesh)

    rng = np.random.default_rng(args.seed)
    b = args.batch
    max_len = args.prompt_len + args.gen

    with mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        payload = None
        if args.weights != "dense":
            params, payload, byte_line = _tt_setup(params, args, cfg)
            print(f"[serve] TT-native mode: {byte_line}")

        prompts = rng.integers(
            0, cfg.vocab_size, size=(b, args.prompt_len), dtype=np.int32
        )
        # encdec archs carry encoder input: each request/row gets a source
        # stream, encoded into the cross-attn memory before decode
        src = None
        if model.populate_memory is not None:
            src = rng.integers(
                0, cfg.vocab_size, size=(b, cfg.frontend_len),
                dtype=np.int32,
            )
        sample_kw = dict(
            src_tokens=src, temperature=args.temperature, top_k=args.top_k,
            seed=args.seed,
        )
        # main run and verify oracle share ONE driver implementation
        # (launch/engine.generate) — --driver picks fused vs python
        run = engine_mod.generate(
            model, params, prompts, args.gen, max_len=max_len,
            driver=args.driver, **sample_kw,
        )

        if args.weights != "dense" and args.verify:
            # reconstruct-then-serve oracle: same payload, dense weights.
            # Materialized HERE only — use --no-verify for the pure-TT
            # resident footprint (verify is on by default as the demo of
            # the logit-parity guarantee)
            from repro.core import TTCompressor as _TTC
            from repro.models.common import logit_parity
            params_rx = _TTC().decompress(payload)
            oracle = engine_mod.generate(
                model, params_rx, prompts, args.gen, max_len=max_len,
                driver=args.driver, **sample_kw,
            )
            d, scale, agree = logit_parity(
                run["prompt_logits"], oracle["prompt_logits"]
            )
            tps_rx = b * (args.gen - 1) / max(oracle["decode_t"], 1e-9)
            agree_line = f"next-token agreement {agree:.2%}"
            if _quant_of(args.weights) is not None:
                # quantization moves logits, and on synthetic spectral-decay
                # weights the distribution is near-flat (argmax ties flip on
                # any perturbation) — report the GATED metric instead:
                # teacher-forced tie-tolerant agreement over every prompt
                # position (see benchmarks/tt_serve.run_quant)
                tf_q = _teacher_forced_logits(model, params, prompts)
                tf_rx = _teacher_forced_logits(model, params_rx, prompts)
                tol = 0.05 * float(np.max(np.abs(tf_rx)))
                top = np.argmax(tf_rx, -1)
                deficit = np.max(tf_q, -1) - np.take_along_axis(
                    tf_q, top[..., None], -1)[..., 0]
                agree_line = (
                    f"tie-tolerant next-token agreement "
                    f"{float(np.mean(deficit <= tol)):.2%} over "
                    f"{top.size} teacher-forced positions")
            print(f"[serve] verify vs reconstruct-then-serve: "
                  f"max|Δlogits| {d:.2e} (scale {scale:.2e}), "
                  f"{agree_line}, "
                  f"reconstruct decode {tps_rx:.1f} tok/s")

    gen = run["gen"]
    tps = b * (args.gen - 1) / max(run["decode_t"], 1e-9)
    mode = "dense" if args.weights == "dense" else f"{args.weights}-native"
    print(f"[serve] ({mode}, driver={args.driver}) prefill "
          f"{args.prompt_len} toks in "
          f"{run['prefill_t']*1e3:.0f}ms; decode {args.gen-1} steps @ "
          f"{tps:.1f} tok/s (batch={b})")
    print(f"[serve] sample generation: {gen[0][:16].tolist()}")
    return {"tok_per_s": tps, "generated": gen}


def serve_http(args) -> None:
    """Server mode: N engine replicas behind the router + HTTP front door.

    Replicas share one params pytree (host memory is shared; each replica
    owns only its cache pool), so N replicas cost N cache pools, not N
    copies of the weights.
    """
    from repro.launch.router import Router
    from repro.launch.server import run_server

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    shd.set_mesh_axis_sizes(mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        if args.weights != "dense":
            params, _, byte_line = _tt_setup(params, args, cfg)
            print(f"[serve] TT-native mode: {byte_line}")
        max_len = args.prompt_len + args.gen
        engines = [
            engine_mod.Engine(
                model, params, slots=args.slots, max_len=max_len,
                chunk_steps=args.chunk_steps,
                temperature=args.temperature, top_k=args.top_k,
                seed=args.seed, admission=args.admission,
            )
            for _ in range(args.replicas)
        ]
        watchdog = (None if args.watchdog_ms is None
                    else args.watchdog_ms / 1e3)
        router = Router(engines, queue_depth=args.queue_depth,
                        watchdog_s=watchdog)
        deadline = (None if args.deadline_ms is None
                    else args.deadline_ms / 1e3)
        print(f"[serve] {args.replicas} replica(s) x {args.slots} slots, "
              f"admission={engines[0].admission}, "
              f"queue_depth={args.queue_depth}"
              + (f", watchdog={watchdog:g}s" if watchdog else ""))
        run_server(router, host=args.host, port=args.port,
                   default_deadline=deadline)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="weights/prompts RNG seed AND the sampling seed "
                         "(row r samples under fold_in(PRNGKey(seed), r))")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 (default) is greedy "
                         "argmax, bit-identical to the pre-sampling driver")
    ap.add_argument("--top-k", type=int, default=None,
                    help="keep only the k highest logits before sampling "
                         "(requires --temperature > 0 to matter)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--driver", choices=engine_mod.DRIVERS, default="fused",
                    help="decode driver: 'fused' runs the whole generation "
                         "as one scanned computation per phase (no per-token "
                         "dispatch); 'python' is the legacy per-token oracle")
    ap.add_argument("--weights", choices=("dense", "tt", "tt-int8"),
                    default="dense",
                    help="tt = serve straight from TT cores (no dense "
                         "weight materialization for eligible layers); "
                         "tt-int8 = same, with int8 cores + symmetric "
                         "scales dequantized inside the fused kernels")
    ap.add_argument("--quant-calib", type=str, default="absmax",
                    help="quantization calibration for --weights tt-int8: "
                         "'absmax' (exact round-trip grid) or 'pXX' "
                         "(XX-th percentile of |w|, clips tail outliers)")
    ap.add_argument("--tt-eps", type=float, default=0.2,
                    help="compression ε for the in-process TT payload")
    ap.add_argument("--tt-alpha", type=float, default=1.0,
                    help="spectral decay of the synthetic trained weights")
    ap.add_argument("--tt-checkpoint", type=str, default=None,
                    help="load the TT payload from this directory "
                         "(checkpoint.save_tt_payload layout); the "
                         "manifest's recorded family must match --arch")
    ap.add_argument("--save-tt-checkpoint", type=str, default=None,
                    help="after in-process compression, save the TT "
                         "payload here (records the model family in the "
                         "manifest for the load-time cross-check)")
    ap.add_argument("--verify", action="store_true", default=True,
                    help="cross-check TT-native logits against the "
                         "reconstruct-then-serve oracle (default ON; this "
                         "materializes the dense weights for the oracle "
                         "pass — use --no-verify for the pure-TT resident "
                         "footprint)")
    ap.add_argument("--no-verify", dest="verify", action="store_false")
    ap.add_argument("--serve", action="store_true",
                    help="server mode: run the HTTP front door instead of "
                         "one batch (POST /v1/generate; see docs/SERVING.md)")
    ap.add_argument("--host", type=str, default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="TCP port (0 = ephemeral, printed at startup)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the router (weights are "
                         "shared; each replica adds one cache pool + worker)")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent requests per replica (cache pool rows)")
    ap.add_argument("--chunk-steps", type=int, default=8,
                    help="fused decode steps per scheduling chunk")
    ap.add_argument("--queue-depth", type=int, default=16,
                    help="max outstanding requests per replica before "
                         "submissions get 429 (bounded admission queue)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="server-wide default per-request deadline; an "
                         "expired request is cancelled (504) and its slot "
                         "freed.  Requests can override via 'deadline_ms'")
    ap.add_argument("--watchdog-ms", type=float, default=None,
                    help="per-chunk heartbeat watchdog: a replica whose "
                         "worker goes stale longer than this while holding "
                         "work is marked suspect (no new placements) until "
                         "it recovers; worker DEATH is always supervised "
                         "(failover + bounded-backoff restart) regardless")
    ap.add_argument("--admission", choices=engine_mod.ADMISSION_MODES,
                    default="auto",
                    help="slot admission: 'scan' = in-scan device-resident "
                         "queue (token-only families), 'boundary' = one "
                         "dispatch per admission between chunks (encdec); "
                         "'auto' picks per family")
    args = ap.parse_args()
    if args.serve:
        serve_http(args)
    else:
        serve(args)


if __name__ == "__main__":
    main()
