"""Fig. 1 workflow benchmark — cross-pod parameter-exchange payload.

The paper's end goal is cutting distributed-learning communication: each
edge node TT-compresses its parameters before transmission (3.4× fewer
parameters on the wire, Table I).  Our multi-pod analogue: pods exchange
parameter *deltas* over the slow DCI link every ``sync_every`` steps
(FedTTD, DiLoCo-style).  This benchmark measures, for a reduced-LM delta
pytree:

  * payload ratio    — TT bytes / dense bytes on the DCI link,
  * roundtrip error  — ||avg_tt - avg_dense|| / ||avg_dense||,
  * error-feedback   — residual norm decay over repeated syncs (shows the
                       compression error does NOT accumulate).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm_compress import CommCompressionConfig, fedttd_roundtrip


def run(verbose: bool = True, n_pods: int = 4, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    # delta tensors with trained-gradient-like decaying spectra
    def delta(shape, alpha=0.8):
        m, n = shape
        k = min(m, n)
        qu, _ = np.linalg.qr(rng.standard_normal((m, k)))
        qv, _ = np.linalg.qr(rng.standard_normal((n, k)))
        s = np.arange(1, k + 1.0) ** -alpha
        return jnp.asarray((qu * s) @ qv.T, jnp.float32)

    shapes = [(1024, 1024), (1024, 2816), (2816, 1024)]   # qwen-0.5b MLP-ish
    cfg = CommCompressionConfig(eps=0.1, max_rank=64)

    rows = []
    for shape in shapes:
        deltas = [delta(shape) for _ in range(n_pods)]
        dense_avg = sum(deltas) / n_pods
        avg, resids, payload = fedttd_roundtrip(deltas, cfg)
        err = float(jnp.linalg.norm(avg - dense_avg)
                    / jnp.linalg.norm(dense_avg))
        resid_frac = float(
            sum(jnp.linalg.norm(r) for r in resids)
            / sum(jnp.linalg.norm(d) for d in deltas))
        rows.append({"shape": shape, "payload_ratio": payload,
                     "roundtrip_err": err, "residual_frac": resid_frac})

    # error feedback: the residual re-enters the next sync's payload, so what
    # the receiver has cumulatively APPLIED converges to the true delta even
    # though each individual payload is lossy.
    target = delta((1024, 1024))
    carried = jnp.zeros_like(target)      # error-feedback accumulator
    applied = jnp.zeros_like(target)      # receiver's cumulative update
    ef_norms = []
    for k in range(6):
        payload_in = (target if k == 0 else jnp.zeros_like(target)) + carried
        avg, resids, _ = fedttd_roundtrip([payload_in], cfg)
        applied = applied + avg
        carried = resids[0]
        ef_norms.append(float(jnp.linalg.norm(applied - target)
                              / jnp.linalg.norm(target)))

    out = {"rows": rows, "error_feedback_norms": ef_norms}
    if verbose:
        print(f"# Cross-pod TT-compressed sync ({n_pods} pods, "
              f"ε={cfg.eps}, max_rank={cfg.max_rank})")
        print("shape,payload_ratio,dci_reduction,roundtrip_err,residual_frac")
        for r in rows:
            print(f"{r['shape'][0]}x{r['shape'][1]},"
                  f"{r['payload_ratio']:.3f},"
                  f"{1 / max(r['payload_ratio'], 1e-9):.1f}x,"
                  f"{r['roundtrip_err']:.4f},{r['residual_frac']:.4f}")
        print("# error-feedback residual fraction per sync:",
              ",".join(f"{x:.3f}" for x in ef_norms))
    return out


if __name__ == "__main__":
    run()
