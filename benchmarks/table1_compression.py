"""Paper Table I — TD method comparison on ResNet-32 (CIFAR-10) parameters.

Uncompressed / Tucker / TRD / TTD on the same parameter set, same ε budget,
same two-phase SVD substrate.  Accuracy is proxied by relative
reconstruction error (no CIFAR-10 in-container; see workload_resnet32.py).

Paper numbers (Table I):
  Uncompressed 1.0×  0.47M        | Tucker 2.8× 0.16M
  TRD          2.7×  0.17M        | TTD    3.4× 0.14M
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import baselines, tt as _tt
from benchmarks.workload_resnet32 import (
    conv_stack,
    resnet32_params,
    total_params,
)

EPS = 0.22   # ε giving Table-I-scale ratios on the α=1.0 spectral proxy


def _tt_dims(shape):
    """Conv kernels (C_out, C_in, 3, 3) → natural 4D; fc stays 2D."""
    return list(shape)


def run(eps: float = EPS, seed: int = 0, verbose: bool = True,
        fast: bool = False) -> Dict:
    params = resnet32_params(seed=seed)
    n_total = total_params(params)
    stack = conv_stack(params)
    aux = n_total - sum(int(w.size) for _, w in stack)   # BN/bias: sent raw

    # fast (CI smoke) mode: TTD only on a prefix of the stack — catches
    # script rot without paying for the full three-method sweep
    methods = ("ttd",) if fast else ("ttd", "tucker", "trd")
    if fast:
        stack = stack[:8]
    rows = []
    for method in methods:
        n_payload = aux
        sq_err = 0.0
        sq_ref = 0.0
        t0 = time.time()
        for _, w in stack:
            if method == "ttd":
                f = _tt.ttd(w, eps=eps, dims=_tt_dims(w.shape))
                rec = np.asarray(_tt.tt_reconstruct(f)).reshape(w.shape)
                n_payload += f.num_params
            elif method == "tucker":
                f = baselines.tucker_hosvd(w, eps=eps)
                rec = np.asarray(baselines.tucker_reconstruct(f))
                n_payload += f.num_params
            else:
                f = baselines.tr_svd(w, eps=eps)
                rec = np.asarray(baselines.tr_reconstruct(f)).reshape(w.shape)
                n_payload += f.num_params
            sq_err += float(np.sum((rec - w) ** 2))
            sq_ref += float(np.sum(w.astype(np.float64) ** 2))
        rel_err = float(np.sqrt(sq_err / sq_ref))
        rows.append({
            "method": method,
            "ratio": n_total / n_payload,
            "final_params_m": n_payload / 1e6,
            "rel_err": rel_err,
            "wall_s": time.time() - t0,
        })

    out = {"eps": eps, "total_params_m": n_total / 1e6, "rows": rows}
    if verbose:
        print(f"# Table I analogue (ε={eps}, uncompressed "
              f"{n_total/1e6:.2f}M params; paper: 0.47M)")
        if fast:
            print("# FAST mode: ttd only, first 8 tensors")
        print("method,comp_ratio,final_params_M,rel_recon_err,wall_s,"
              "paper_ratio")
        paper = {"ttd": 3.4, "tucker": 2.8, "trd": 2.7}
        for r in rows:
            print(f"{r['method']},{r['ratio']:.2f},"
                  f"{r['final_params_m']:.3f},{r['rel_err']:.4f},"
                  f"{r['wall_s']:.1f},{paper[r['method']]}")
    return out


if __name__ == "__main__":
    run()
