"""The paper's benchmark workload: ResNet-32 (CIFAR-10) parameters.

The paper compresses a *trained* ResNet-32 (0.47M params, Table I).  We have
no CIFAR-10 in this container, so we synthesize parameters with the spectral
profile of trained convnets instead of training one: trained conv/fc weight
matricizations exhibit power-law singular-value decay (Martin & Mahoney,
2021 — "heavy-tailed self-regularization"), which is precisely what makes
δ-truncated TTD effective.  Random i.i.d. Gaussian weights have a
quarter-circle (flat) spectrum and would understate every method's ratio
equally.  We therefore draw each weight as U diag(s) V^T with s_i ∝ i^{-α},
α = 1.0 (mid-range of the trained-model fits), and report *reconstruction
error* as the accuracy proxy.  This assumption is recorded in DESIGN.md.

Architecture (He et al. 2016, CIFAR variant, n = 5 → 6n+2 = 32 layers):
  conv1   3×3×3×16
  stage1  5 blocks × 2 × (3×3×16×16)
  stage2  3×3×16×32 + 3×3×32×32 ×9   (first block downsamples)
  stage3  3×3×32×64 + 3×3×64×64 ×9
  fc      64×10 (+bias)
  per-conv BN (γ, β)
Total ≈ 0.467M parameters — matching Table I's 0.47M.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def _spectral_weight(rng: np.random.Generator, shape: Tuple[int, ...],
                     alpha: float = 1.0) -> np.ndarray:
    """Weight tensor whose (out, in·kh·kw) matricization has s_i ∝ i^-alpha."""
    mat_shape = (shape[0], int(np.prod(shape[1:])))
    m, n = mat_shape
    k = min(m, n)
    # Haar-ish bases via QR of Gaussians.
    qu, _ = np.linalg.qr(rng.standard_normal((m, k)))
    qv, _ = np.linalg.qr(rng.standard_normal((n, k)))
    s = (np.arange(1, k + 1, dtype=np.float64) ** (-alpha))
    w = (qu * s) @ qv.T
    # He-init scale, as trained nets roughly preserve init magnitude.
    w *= np.sqrt(2.0 / np.prod(shape[1:])) / np.linalg.norm(w) * np.sqrt(w.size)
    return w.reshape(shape).astype(np.float32)


def resnet32_params(seed: int = 0, alpha: float = 1.0) -> Dict[str, np.ndarray]:
    """Parameter pytree (name → array), conv kernels as (C_out, C_in, kh, kw)."""
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}

    def conv(name: str, c_out: int, c_in: int):
        params[f"{name}.w"] = _spectral_weight(rng, (c_out, c_in, 3, 3), alpha)
        params[f"{name}.bn.g"] = np.ones((c_out,), np.float32)
        params[f"{name}.bn.b"] = np.zeros((c_out,), np.float32)

    conv("conv1", 16, 3)
    widths = [16, 32, 64]
    for s, w in enumerate(widths):
        w_in = 16 if s == 0 else widths[s - 1]
        for b in range(5):
            cin = w_in if b == 0 else w
            conv(f"s{s}.b{b}.conv1", w, cin)
            conv(f"s{s}.b{b}.conv2", w, w)
    params["fc.w"] = _spectral_weight(rng, (10, 64), alpha)
    params["fc.b"] = np.zeros((10,), np.float32)
    return params


def total_params(params: Dict[str, np.ndarray]) -> int:
    return int(sum(int(p.size) for p in params.values()))


def conv_stack(params: Dict[str, np.ndarray]) -> List[Tuple[str, np.ndarray]]:
    """The TT targets: every conv/fc weight tensor, in network order."""
    return [(k, v) for k, v in params.items() if k.endswith(".w")]


if __name__ == "__main__":
    p = resnet32_params()
    print(f"resnet32 params: {total_params(p):,} "
          f"({total_params(p) / 1e6:.2f}M, paper: 0.47M)")
