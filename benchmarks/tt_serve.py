"""TT-serve benchmark — reconstruct-then-serve vs TT-native decode.

Compares the two receiving-node strategies for a TT-shipped model on the
serving workload that matters (memory-bound batched decode):

  * ``reconstruct``  — Fig. 1 baseline: materialize every dense weight via
                       eq. (1)/(2), then serve with dense matmuls.
  * ``tt-native``    — contract activations straight against the cores
                       (``core/tt_linear`` + fused ``kernels/tt_contract``);
                       dense matrices for eligible layers never exist.

Reports tokens/s and resident weight bytes for both, and asserts the two
produce the same logits (same cores, same contraction order — only
rounding differs).  ``fast=True`` is the CI smoke lane; ``run_families``
sweeps one reduced config per family (transformer, encdec, mamba2, rglru,
MoE) so TT-native coverage regressions fail the build.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def _decode(model, params, prompts, gen, max_len, driver="fused"):
    """One serving run via the engine (single source of truth for
    prefill-by-stepping + greedy decode + timing boundaries)."""
    from repro.launch.engine import generate
    out = generate(model, params, prompts, gen, max_len=max_len,
                   driver=driver)
    return out["decode_t"], out["prompt_logits"]


def run(fast: bool = False, arch: str = "gemma3-1b", eps: float = 0.2,
        write_json: bool = True):
    from repro.configs import get_config
    from repro.core import (
        CompressionPolicy, TTCompressor, spectral_decay_pytree,
        tt_param_bytes,
    )
    from repro.models import common as model_common
    from repro.models.registry import build

    b, prompt_len, gen = (2, 8, 8) if fast else (4, 32, 32)
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=eps, min_size=8192))
    payload, report = comp.compress(params)

    t0 = time.time()
    params_rx = comp.decompress(payload)
    reconstruct_t = time.time() - t0
    t0 = time.time()
    params_tt = model_common.tt_native_params(payload, family=cfg.family)
    convert_t = time.time() - t0

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (b, prompt_len), np.int32)
    max_len = prompt_len + gen

    rows = []
    logits = {}
    for mode, p in (("reconstruct", params_rx), ("tt-native", params_tt)):
        dt, prompt_logits = _decode(model, p, prompts, gen, max_len)
        logits[mode] = prompt_logits
        rows.append((
            mode,
            b * (gen - 1) / max(dt, 1e-9),
            tt_param_bytes(p),
            reconstruct_t if mode == "reconstruct" else convert_t,
        ))

    print(f"\nTT-serve ({arch} reduced, ε={eps}, batch={b}, gen={gen}; "
          f"payload {report.ratio:.2f}x params)")
    print(f"{'mode':<14}{'tok/s':>10}{'weight bytes':>16}{'setup s':>10}")
    for mode, tps, bytes_, setup in rows:
        print(f"{mode:<14}{tps:>10.1f}{bytes_:>16,}{setup:>10.2f}")

    d, scale, agree = model_common.logit_parity(
        logits["tt-native"], logits["reconstruct"]
    )
    print(f"logit check: max|Δ| {d:.2e} (scale {scale:.2e}), "
          f"agreement {agree:.2%}")
    assert d <= max(0.05 * scale, 1e-3), (d, scale)
    dense_b = rows[0][2]
    tt_b = rows[1][2]
    assert tt_b < dense_b, (tt_b, dense_b)
    print(f"resident-weight reduction: {dense_b / tt_b:.2f}x")
    result = {"arch": arch, "max_diff": d, "agreement": agree,
              "dense_bytes": dense_b, "tt_bytes": tt_b,
              "reconstruct_tps": rows[0][1], "tt_native_tps": rows[1][1]}
    if write_json:
        from benchmarks.record import write_bench
        write_bench("tt_serve", {"archs": {arch: result}})
    return result


def _teacher_logits(model, params, prompts):
    """Teacher-forced per-position logits: drive ``decode_step`` across the
    prompt and stack every position's next-token logits -> (b, S-1, V).
    This is the measured surface for the quantized agreement gate —
    ``generate``'s ``prompt_logits`` is last-position only, which would
    reduce the gate to a handful of samples."""
    import jax.numpy as jnp
    b, s = prompts.shape
    cache = model.init_cache(b, s)
    outs = []
    for t in range(s - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray(prompts[:, t:t + 1]))
        outs.append(np.asarray(logits, np.float32).reshape(b, -1))
    return np.stack(outs, 1)


def run_quant(fast: bool = False, arch: str = "gemma3-1b", eps: float = 0.2,
              write_json: bool = True, min_agreement: float = 0.99,
              agree_tol: float = 0.05,
              min_vs_tt: float = 1.8, min_vs_dense: float = 3.9):
    """Quantized TT serving gate: int8 cores + fused in-kernel dequant.

    Serves the same payload three ways — reconstruct-then-serve (dense),
    TT-native (wide cores), TT-native int8 — and gates on the quantized
    contract:

      * teacher-forced next-token agreement vs the dense oracle ≥ 99%,
        measured over every prompt position (b×(S-1) positions via
        ``_teacher_logits``, so the bound is measured, not vacuous).
        Agreement is TIE-TOLERANT: a position agrees when the quantized
        model's argmax matches the oracle token, or scores it within
        ``agree_tol``·(logit scale) of its own argmax.  On synthetic
        spectral-decay weights the predictive distribution is near-flat
        (top-1/top-2 gaps ~1% of logit scale — random weights have nothing
        to be confident about), so raw argmax between ANY two
        numerically-differing implementations is tie-breaking noise there;
        the tolerance is the same 5%-of-scale bound the wide-TT parity
        gate uses, and a real quantization bug (wrong scale, overflow,
        missing dequant) blows through it at once.  Raw argmax agreement
        is recorded alongside.
      * TT-served-leaf resident bytes (what the ``tt_contract`` kernels
        stream — ``tt_leaf_bytes``) shrink ≥1.8x vs the wide (bf16) cores
        and ≥3.9x vs the dense form of those same leaves.  Raw leaves
        (embeddings, norms) are identical across all three modes; total
        resident bytes are recorded alongside but not gated, since the raw
        remainder dilutes the ratio without saying anything about the
        quantization.
    """
    from repro.configs import get_config
    from repro.core import (
        CompressionPolicy, TTCompressor, spectral_decay_pytree,
        tt_leaf_bytes, tt_param_bytes,
    )
    from repro.models import common as model_common
    from repro.models.registry import build

    # the agreement gate needs position count: b×(prompt_len-1) ≥ 252 keeps
    # the measurement granularity (1/positions) well under the 1% bound
    b, prompt_len, gen = (4, 64, 8) if fast else (4, 64, 32)
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=eps, min_size=8192))
    payload, report = comp.compress(params)

    params_rx = comp.decompress(payload)
    params_tt = model_common.tt_native_params(payload, family=cfg.family)
    params_q = model_common.tt_native_params(
        payload, family=cfg.family, quant="int8"
    )

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (b, prompt_len), np.int32)
    max_len = prompt_len + gen

    rows, tf = [], {}
    for mode, p in (("dense", params_rx), ("tt", params_tt),
                    ("tt-int8", params_q)):
        dt, _ = _decode(model, p, prompts, gen, max_len)
        tf[mode] = _teacher_logits(model, p, prompts)
        rows.append((mode, b * (gen - 1) / max(dt, 1e-9), tt_param_bytes(p)))

    lrx, lq = tf["dense"], tf["tt-int8"]
    positions = lrx.shape[0] * lrx.shape[1]
    scale = float(np.max(np.abs(lrx)))
    oracle = np.argmax(lrx, -1)                       # (b, S-1)
    raw_agree = float(np.mean(np.argmax(lq, -1) == oracle))
    # tie-tolerant: the quantized model must score the oracle token within
    # agree_tol*scale of its own argmax (0 deficit == raw argmax match)
    deficit = np.max(lq, -1) - np.take_along_axis(
        lq, oracle[..., None], -1)[..., 0]
    agree = float(np.mean(deficit <= agree_tol * scale))
    # the wide cores are held to a 5x tighter bound — only quantization is
    # allowed to move logits materially (exact argmax would flip on
    # rounding noise at the near-tied positions)
    tt_deficit = np.max(tf["tt"], -1) - np.take_along_axis(
        tf["tt"], oracle[..., None], -1)[..., 0]
    tt_agree = float(np.mean(tt_deficit <= 0.2 * agree_tol * scale))
    wide_leaf, dense_leaf = tt_leaf_bytes(params_tt)
    q_leaf, _ = tt_leaf_bytes(params_q)

    print(f"\nTT-quant ({arch} reduced, ε={eps}, int8 cores, batch={b}, "
          f"gen={gen})")
    print(f"{'mode':<12}{'tok/s':>10}{'total bytes':>14}")
    for mode, tps, bytes_ in rows:
        print(f"{mode:<12}{tps:>10.1f}{bytes_:>14,}")
    print(f"TT-served leaves: bf16-TT {wide_leaf:,} -> int8 {q_leaf:,} "
          f"({wide_leaf / q_leaf:.2f}x; vs dense form "
          f"{dense_leaf / q_leaf:.2f}x)")
    print(f"next-token agreement vs dense oracle: {agree:.2%} "
          f"(tie-tolerant, tol {agree_tol:.0%} of logit scale; raw argmax "
          f"{raw_agree:.2%}) over {positions} teacher-forced positions")

    assert tt_agree == 1.0, ("wide-TT logits drifted from dense", tt_agree)
    assert agree >= min_agreement, (agree, min_agreement)
    assert wide_leaf / q_leaf >= min_vs_tt, (wide_leaf, q_leaf, min_vs_tt)
    assert dense_leaf / q_leaf >= min_vs_dense, (
        dense_leaf, q_leaf, min_vs_dense)

    result = {
        "arch": arch, "agreement": agree, "raw_argmax_agreement": raw_agree,
        "agree_tol_frac": agree_tol,
        "positions": positions,
        "tt_leaf_bytes": wide_leaf, "tt_int8_leaf_bytes": q_leaf,
        "dense_leaf_bytes": dense_leaf,
        "leaf_reduction_vs_tt": wide_leaf / q_leaf,
        "leaf_reduction_vs_dense": dense_leaf / q_leaf,
        "modes": {
            mode: {"tok_per_s": tps, "total_bytes": bytes_}
            for mode, tps, bytes_ in rows
        },
    }
    if write_json:
        from benchmarks.record import write_bench
        write_bench("tt_quant", result)
    return result


# one reduced config per architecture family: transformer (dense), encdec,
# ssm (mamba2), hybrid (rglru), and MoE expert banks
FAMILY_ARCHS = (
    "gemma3-1b",
    "seamless-m4t-large-v2",
    "mamba2-1.3b",
    "recurrentgemma-2b",
    "olmoe-1b-7b",
)


def run_families(fast: bool = False, eps: float = 0.2):
    """Coverage lane: TT-native serving across EVERY family in the zoo.

    Each family must (a) pass the shared logit-parity bound against
    reconstruct-then-serve and (b) shrink resident weight bytes vs dense —
    the two asserts inside ``run`` — so a family regressing to
    reconstruct-on-load fails the build, not just a benchmark number."""
    results = [run(fast=fast, arch=arch, eps=eps, write_json=False)
               for arch in FAMILY_ARCHS]
    print("\nTT-native coverage (family sweep)")
    print(f"{'arch':<24}{'max|Δ|':>10}{'agree':>8}{'byte reduction':>16}")
    for r in results:
        print(f"{r['arch']:<24}{r['max_diff']:>10.2e}"
              f"{r['agreement']:>8.0%}"
              f"{r['dense_bytes'] / r['tt_bytes']:>15.2f}x")
    from benchmarks.record import write_bench
    write_bench("tt_serve", {"archs": {r["arch"]: r for r in results}})
    return results


if __name__ == "__main__":
    import sys
    if "--families" in sys.argv:
        run_families(fast="--fast" in sys.argv)
    elif "--quant" in sys.argv:
        run_quant(fast="--fast" in sys.argv)
    else:
        run(fast="--fast" in sys.argv)
