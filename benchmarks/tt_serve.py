"""TT-serve benchmark — reconstruct-then-serve vs TT-native decode.

Compares the two receiving-node strategies for a TT-shipped model on the
serving workload that matters (memory-bound batched decode):

  * ``reconstruct``  — Fig. 1 baseline: materialize every dense weight via
                       eq. (1)/(2), then serve with dense matmuls.
  * ``tt-native``    — contract activations straight against the cores
                       (``core/tt_linear`` + fused ``kernels/tt_contract``);
                       dense matrices for eligible layers never exist.

Reports tokens/s and resident weight bytes for both, and asserts the two
produce the same logits (same cores, same contraction order — only
rounding differs).  ``fast=True`` is the CI smoke lane; ``run_families``
sweeps one reduced config per family (transformer, encdec, mamba2, rglru,
MoE) so TT-native coverage regressions fail the build.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def _decode(model, params, prompts, gen, max_len, driver="fused"):
    """One serving run via the engine (single source of truth for
    prefill-by-stepping + greedy decode + timing boundaries)."""
    from repro.launch.engine import generate
    out = generate(model, params, prompts, gen, max_len=max_len,
                   driver=driver)
    return out["decode_t"], out["prompt_logits"]


def run(fast: bool = False, arch: str = "gemma3-1b", eps: float = 0.2,
        write_json: bool = True):
    from repro.configs import get_config
    from repro.core import (
        CompressionPolicy, TTCompressor, spectral_decay_pytree,
        tt_param_bytes,
    )
    from repro.models import common as model_common
    from repro.models.registry import build

    b, prompt_len, gen = (2, 8, 8) if fast else (4, 32, 32)
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=eps, min_size=8192))
    payload, report = comp.compress(params)

    t0 = time.time()
    params_rx = comp.decompress(payload)
    reconstruct_t = time.time() - t0
    t0 = time.time()
    params_tt = model_common.tt_native_params(payload, family=cfg.family)
    convert_t = time.time() - t0

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (b, prompt_len), np.int32)
    max_len = prompt_len + gen

    rows = []
    logits = {}
    for mode, p in (("reconstruct", params_rx), ("tt-native", params_tt)):
        dt, prompt_logits = _decode(model, p, prompts, gen, max_len)
        logits[mode] = prompt_logits
        rows.append((
            mode,
            b * (gen - 1) / max(dt, 1e-9),
            tt_param_bytes(p),
            reconstruct_t if mode == "reconstruct" else convert_t,
        ))

    print(f"\nTT-serve ({arch} reduced, ε={eps}, batch={b}, gen={gen}; "
          f"payload {report.ratio:.2f}x params)")
    print(f"{'mode':<14}{'tok/s':>10}{'weight bytes':>16}{'setup s':>10}")
    for mode, tps, bytes_, setup in rows:
        print(f"{mode:<14}{tps:>10.1f}{bytes_:>16,}{setup:>10.2f}")

    d, scale, agree = model_common.logit_parity(
        logits["tt-native"], logits["reconstruct"]
    )
    print(f"logit check: max|Δ| {d:.2e} (scale {scale:.2e}), "
          f"agreement {agree:.2%}")
    assert d <= max(0.05 * scale, 1e-3), (d, scale)
    dense_b = rows[0][2]
    tt_b = rows[1][2]
    assert tt_b < dense_b, (tt_b, dense_b)
    print(f"resident-weight reduction: {dense_b / tt_b:.2f}x")
    result = {"arch": arch, "max_diff": d, "agreement": agree,
              "dense_bytes": dense_b, "tt_bytes": tt_b,
              "reconstruct_tps": rows[0][1], "tt_native_tps": rows[1][1]}
    if write_json:
        from benchmarks.record import write_bench
        write_bench("tt_serve", {"archs": {arch: result}})
    return result


# one reduced config per architecture family: transformer (dense), encdec,
# ssm (mamba2), hybrid (rglru), and MoE expert banks
FAMILY_ARCHS = (
    "gemma3-1b",
    "seamless-m4t-large-v2",
    "mamba2-1.3b",
    "recurrentgemma-2b",
    "olmoe-1b-7b",
)


def run_families(fast: bool = False, eps: float = 0.2):
    """Coverage lane: TT-native serving across EVERY family in the zoo.

    Each family must (a) pass the shared logit-parity bound against
    reconstruct-then-serve and (b) shrink resident weight bytes vs dense —
    the two asserts inside ``run`` — so a family regressing to
    reconstruct-on-load fails the build, not just a benchmark number."""
    results = [run(fast=fast, arch=arch, eps=eps, write_json=False)
               for arch in FAMILY_ARCHS]
    print("\nTT-native coverage (family sweep)")
    print(f"{'arch':<24}{'max|Δ|':>10}{'agree':>8}{'byte reduction':>16}")
    for r in results:
        print(f"{r['arch']:<24}{r['max_diff']:>10.2e}"
              f"{r['agreement']:>8.0%}"
              f"{r['dense_bytes'] / r['tt_bytes']:>15.2f}x")
    from benchmarks.record import write_bench
    write_bench("tt_serve", {"archs": {r["arch"]: r for r in results}})
    return results


if __name__ == "__main__":
    import sys
    if "--families" in sys.argv:
        run_families(fast="--fast" in sys.argv)
    else:
        run(fast="--fast" in sys.argv)
