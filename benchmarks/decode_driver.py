"""Decode-driver benchmark: python loop vs fused scan vs continuous batching.

Three serving strategies over the SAME decode_step, dense and TT-native:

  * ``python``     — one jitted decode_step per token, driven from Python
                     (a dispatch round-trip + sample/argmax host sync per
                     token).
  * ``fused``      — the whole generation as one scanned computation per
                     phase (``launch/engine.generate(driver="fused")``).
  * ``continuous`` — slot-based continuous batching over the fused driver
                     on a heterogeneous request mix, against the padded
                     lockstep baseline (same request mix, same fused
                     stepper, prompts/gens padded to the batch max).

Asserts (the CI smoke lane gate):
  * fused and python produce token-for-token identical generations —
    greedy AND under temperature/top-k sampling (fixed seed);
  * fused decode tok/s >= python decode tok/s (dense AND tt weights);
  * continuous batching beats padded lockstep on aggregate tok/s;
  * encdec requests under continuous batching (encoder memory computed at
    admission) match isolated runs token-for-token.

Results land in ``BENCH_decode.json`` (see benchmarks/record.py).
"""

from __future__ import annotations

import time

import jax
import numpy as np


def _tt_params(model, cfg, eps: float = 0.2):
    from repro.core import (
        CompressionPolicy, TTCompressor, spectral_decay_pytree,
    )
    from repro.models import common as model_common

    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=eps, min_size=8192))
    payload, _ = comp.compress(params)
    return model_common.tt_native_params(payload, family=cfg.family)


def _timed_generate(model, params, prompts, gen, driver, repeats=2):
    """Best-of-``repeats`` decode timing (first call per driver compiles —
    every timing below is from a warm cache)."""
    from repro.launch.engine import generate

    # share one jitted step across the python-driver repeats so only the
    # first (warmup) call pays the trace+compile
    decode = (jax.jit(model.decode_step, donate_argnums=(1,))
              if driver == "python" else None)
    best = None
    for _ in range(repeats + 1):        # +1 warmup
        out = generate(model, params, prompts, int(gen), driver=driver,
                       decode=decode)
        if best is None or out["decode_t"] < best["decode_t"]:
            best = out
    return best


def _driver_faceoff(model, cfg, params, b, plen, gen, label):
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (b, plen), np.int32)
    py = _timed_generate(model, params, prompts, gen, "python")
    fu = _timed_generate(model, params, prompts, gen, "fused")
    parity = bool(np.array_equal(py["gen"], fu["gen"]))
    tps = lambda o: b * (gen - 1) / max(o["decode_t"], 1e-9)  # noqa: E731
    row = {
        "python_tps": tps(py),
        "fused_tps": tps(fu),
        "speedup": tps(fu) / max(tps(py), 1e-9),
        "token_parity": parity,
    }
    print(f"{label:<10}{row['python_tps']:>14.1f}{row['fused_tps']:>12.1f}"
          f"{row['speedup']:>9.2f}x   parity={parity}")
    assert parity, f"{label}: fused generation diverged from python loop"
    return row


def _sampled_faceoff(model, cfg, params, b, plen, gen, label,
                     temperature=0.8, top_k=50, seed=7):
    """Stochastic-sampling lane: both drivers under the same fixed seed
    must emit identical tokens (the PRNG-carrying scan contract)."""
    from repro.launch.engine import generate

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (b, plen), np.int32)
    kw = dict(temperature=temperature, top_k=top_k, seed=seed)
    py = generate(model, params, prompts, int(gen), driver="python", **kw)
    fu = generate(model, params, prompts, int(gen), driver="fused", **kw)
    fu2 = generate(model, params, prompts, int(gen), driver="fused", **kw)
    parity = bool(np.array_equal(py["gen"], fu["gen"])
                  and np.array_equal(fu["gen"], fu2["gen"]))
    tps = b * (gen - 1) / max(min(fu["decode_t"], fu2["decode_t"]), 1e-9)
    print(f"{label:<10}{'':>14}{tps:>12.1f}{'':>10}   parity={parity} "
          f"(T={temperature}, top_k={top_k}, seed={seed})")
    assert parity, f"{label}: sampled fused generation diverged from python"
    return {"fused_tps": tps, "token_parity": parity,
            "temperature": temperature, "top_k": top_k, "seed": seed}


def _encdec_continuous(fast: bool, arch="seamless-m4t-large-v2"):
    """Encdec under continuous batching: requests carry encoder input,
    admission runs the encode, and every staggered completion must match
    its isolated run token-for-token (the PR 4 hole this lane now gates)."""
    from repro.configs import get_config
    from repro.launch.engine import Engine, generate
    from repro.models.registry import build

    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    n_req = 4 if fast else 6
    reqs = []
    for _ in range(n_req):
        plen = 2 + int(rng.integers(0, 3))
        slen = 3 + int(rng.integers(0, cfg.frontend_len - 3))
        reqs.append((
            rng.integers(0, cfg.vocab_size, (plen,), np.int32),
            4 + int(rng.integers(0, 3)),
            rng.integers(0, cfg.vocab_size, (slen,), np.int32),
        ))
    eng = Engine(model, params, slots=2, max_len=16, chunk_steps=3)
    uids = [eng.submit(p, g, src_tokens=s) for p, g, s in reqs]
    done = {c.uid: c for c in eng.run()}
    parity = True
    for uid, (p, g, s) in zip(uids, reqs):
        iso = generate(model, params, p[None], g, driver="fused",
                       src_tokens=s[None])
        parity &= bool(np.array_equal(done[uid].tokens, iso["gen"][0]))
    occ = eng.slot_steps / max(eng.steps * eng.slots, 1)
    print(f"\nencdec continuous batching ({n_req} requests w/ encoder "
          f"input): staggered==isolated parity={parity}, "
          f"occupancy {occ:.0%}")
    assert parity, "encdec continuous batching diverged from isolated runs"
    return {"requests": n_req, "token_parity": parity, "occupancy": occ}


def _request_mix(cfg, n_small, n_big, rng):
    """Heterogeneous arrival stream: each long request arrives followed by
    a run of short ones.  Padded lockstep groups in arrival order, so every
    group containing a long request stalls its short neighbours for the
    long one's full length; the continuous engine instead parks the longs
    on their own slots and streams the shorts through the rest."""
    reqs = []
    per_big = max(n_small // max(n_big, 1), 1)
    for b in range(n_big):
        plen, gen = 6 + int(rng.integers(0, 4)), 32
        reqs.append((rng.integers(0, cfg.vocab_size, (plen,), np.int32),
                     gen))
        take = per_big if b < n_big - 1 else n_small - per_big * (n_big - 1)
        for _ in range(take):
            plen, gen = 2 + int(rng.integers(0, 2)), 3
            reqs.append((rng.integers(0, cfg.vocab_size, (plen,), np.int32),
                         gen))
    return reqs


def _continuous_vs_lockstep(model, cfg, params, reqs, slots, chunk_steps):
    from repro.launch.engine import Engine, generate

    useful = sum(gen for _, gen in reqs)

    def lockstep():
        total_t = 0.0
        for lo in range(0, len(reqs), slots):
            group = reqs[lo:lo + slots]
            maxp = max(len(p) for p, _ in group)
            maxg = max(g for _, g in group)
            padded = np.zeros((len(group), maxp), np.int32)
            for i, (p, _) in enumerate(group):
                padded[i, :len(p)] = p
            t0 = time.time()
            generate(model, params, padded, maxg, driver="fused")
            total_t += time.time() - t0
        return total_t

    def continuous():
        max_len = max(len(p) + g for p, g in reqs) + 1
        eng = Engine(model, params, slots=slots, max_len=max_len,
                     chunk_steps=chunk_steps)
        for p, g in reqs:
            eng.submit(p, g)
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        assert len(done) == len(reqs), (len(done), len(reqs))
        return dt, eng

    lockstep()                           # compile both paths before timing
    dt_cont, _ = continuous()            # (this one also pays the compiles)
    dt_lock = min(lockstep(), lockstep())
    dt_cont2, eng = continuous()
    dt_cont3, _ = continuous()
    dt_cont = min(dt_cont, dt_cont2, dt_cont3)
    row = {
        "requests": len(reqs),
        "useful_tokens": useful,
        "lockstep_tps": useful / max(dt_lock, 1e-9),
        "continuous_tps": useful / max(dt_cont, 1e-9),
        "speedup": dt_lock / max(dt_cont, 1e-9),
        "fused_steps": eng.steps,
        "occupancy": eng.slot_steps / max(eng.steps * eng.slots, 1),
    }
    print(f"\ncontinuous batching ({len(reqs)} heterogeneous requests, "
          f"{slots} slots, chunk={chunk_steps}):")
    print(f"  lockstep padded {row['lockstep_tps']:>8.1f} tok/s   "
          f"continuous {row['continuous_tps']:>8.1f} tok/s   "
          f"({row['speedup']:.2f}x, occupancy {row['occupancy']:.0%})")
    return row


def run(fast: bool = False, arch: str = "gemma3-1b"):
    from benchmarks.record import write_bench
    from repro.configs import get_config
    from repro.models.registry import build

    b, plen, gen = (2, 6, 16) if fast else (4, 16, 48)
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    print(f"\ndecode drivers ({arch} reduced, batch={b}, prompt={plen}, "
          f"gen={gen})")
    print(f"{'weights':<10}{'python tok/s':>14}{'fused tok/s':>12}"
          f"{'speedup':>10}")
    results = {"arch": arch, "batch": b, "prompt_len": plen, "gen": gen}
    results["dense"] = _driver_faceoff(model, cfg, params, b, plen, gen,
                                       "dense")
    params_tt = _tt_params(model, cfg)
    results["tt"] = _driver_faceoff(model, cfg, params_tt, b, plen, gen,
                                    "tt-native")
    results["sampled"] = _sampled_faceoff(model, cfg, params, b, plen, gen,
                                          "sampled")

    rng = np.random.default_rng(1)
    n_small, n_big = (7, 2) if fast else (9, 3)
    reqs = _request_mix(cfg, n_small, n_big, rng)
    results["continuous"] = _continuous_vs_lockstep(
        model, cfg, params, reqs, slots=3 if fast else 4,
        chunk_steps=4,
    )
    results["encdec_continuous"] = _encdec_continuous(fast)

    assert results["dense"]["speedup"] >= 1.0, results["dense"]
    assert results["tt"]["speedup"] >= 1.0, results["tt"]
    assert results["continuous"]["speedup"] > 1.0, results["continuous"]
    assert results["sampled"]["token_parity"], results["sampled"]
    assert results["encdec_continuous"]["token_parity"], (
        results["encdec_continuous"])
    write_bench("decode", results)
    return results


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
