"""Batched compression planner/executor benchmark — the TTD-Engine batching
claim at the framework level.

Compresses the ResNet-32 parameter set (the paper's Table-I workload) twice:
once through the original serial per-parameter loop and once through the
batched planner (``core/plan.py`` + ``core/batch_exec.py``), verifying

  * the bucket plan is bitwise-identical across runs (fingerprint equality),
  * batched reconstructions match the serial oracle within the policy ε,
  * the batched path issues >= 2x fewer kernel dispatches.

Accounting: the serial loop launches one SVD executable per TT-sweep step
per parameter ((d-1) per tensor); the batched path launches ONE fused
executable per shape bucket.  Wall-clock on this CPU container tracks
dispatch+retrace overhead, which is exactly what bucketing amortizes.
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core.compression import CompressionPolicy, TTCompressor
from benchmarks.workload_resnet32 import resnet32_params, total_params

EPS = 0.22    # matched to table1_compression.py


def _rel_err(params, restored) -> float:
    sq_err = sq_ref = 0.0
    for k, w in params.items():
        r = np.asarray(restored[k], np.float64).reshape(np.shape(w))
        w = np.asarray(w, np.float64)
        sq_err += float(np.sum((r - w) ** 2))
        sq_ref += float(np.sum(w ** 2))
    return float(np.sqrt(sq_err / max(sq_ref, 1e-30)))


def run(eps: float = EPS, seed: int = 0, verbose: bool = True,
        fast: bool = False) -> Dict:
    params = resnet32_params(seed=seed)
    if fast:
        # CI smoke: one stage's worth of convs — same code paths (multi-
        # member bucket + singleton), a fraction of the SVD work
        params = {k: v for k, v in params.items() if k.startswith("s1.")}
    n_total = total_params(params)
    policy = CompressionPolicy(
        eps=eps, min_dims=3, svd_method="library",
        hbd_impl="unblocked",
    )
    comp = TTCompressor(policy)

    # --- plan determinism: two independent planning passes ---
    from repro.core.plan import build_plan
    p1 = build_plan(params, policy)
    p2 = build_plan(params, policy)
    assert p1.fingerprint == p2.fingerprint, "plan must be deterministic"

    # --- batched path ---
    t0 = time.time()
    compressed_b, report_b = comp.compress(params, plan="batched")
    wall_b = time.time() - t0
    restored_b = comp.decompress(compressed_b)
    err_b = _rel_err(params, restored_b)
    stats = report_b.exec_stats

    # --- serial oracle ---
    t0 = time.time()
    compressed_s, report_s = comp.compress(params, plan="serial")
    wall_s = time.time() - t0
    restored_s = comp.decompress(compressed_s)
    err_s = _rel_err(params, restored_s)

    out = {
        "eps": eps,
        "total_params_m": n_total / 1e6,
        "plan_fingerprint": p1.fingerprint,
        "buckets": len(p1.buckets),
        "tt_params": p1.tt_params,
        "batched": {
            "ratio": report_b.ratio, "rel_err": err_b, "wall_s": wall_b,
            "dispatches": stats.total_dispatches,
            "bucket_launches": stats.bucket_launches,
        },
        "serial": {
            "ratio": report_s.ratio, "rel_err": err_s, "wall_s": wall_s,
            "dispatches": stats.serial_equiv_dispatches,
        },
        "dispatch_reduction": stats.dispatch_reduction,
    }
    if verbose:
        print(f"# Batched TT-SVD compression (ResNet-32 params, ε={eps})")
        print(p1.describe())
        print(f"plan fingerprint: {p1.fingerprint[:16]}… (deterministic: ok)")
        print("path,comp_ratio,rel_recon_err,dispatches,wall_s")
        print(f"batched,{report_b.ratio:.2f},{err_b:.4f},"
              f"{stats.total_dispatches},{wall_b:.1f}")
        print(f"serial,{report_s.ratio:.2f},{err_s:.4f},"
              f"{stats.serial_equiv_dispatches},{wall_s:.1f}")
        print(f"# dispatch reduction: {stats.dispatch_reduction:.1f}x "
              f"(>=2x required), eps bound holds: "
              f"{err_b <= eps + 1e-4} / {err_s <= eps + 1e-4}")
    assert err_b <= eps + 1e-4, f"batched ε bound violated: {err_b} > {eps}"
    assert out["dispatch_reduction"] >= 2.0, \
        f"batched path must halve dispatches, got {out['dispatch_reduction']}"
    return out


if __name__ == "__main__":
    run()
