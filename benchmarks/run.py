"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table3,comm,roofline]
    python -m benchmarks.run --fast            # CI smoke lane (small sweeps)

  table1    Paper Table I   — TD-method comparison on ResNet-32 params
  table3    Paper Table III — TTD phase breakdown, baseline vs TT-Edge
  batched   Batched planner — bucketed one-launch compression vs serial
  comm      Paper Fig. 1    — cross-pod TT-compressed sync payload
  roofline  §Roofline       — per-cell roofline table from the dry-run
  kernels   Pallas kernel block-shape sweeps vs ref oracles (quick)
  tt_serve  TT-native serving — reconstruct-then-serve vs decode straight
            from TT cores (tok/s + resident weight bytes)
  tt_families  TT-native coverage sweep — logit parity + byte reduction on
            one reduced config per family (transformer/encdec/mamba2/
            rglru/MoE); a family regressing to reconstruct fails the lane.
            Also runs the quantized gate (see tt_quant) on reduced gemma3.
  tt_quant  Quantized TT serving — int8 cores + fused in-kernel dequant on
            reduced gemma3; gates ≥99% next-token agreement vs the dense
            oracle and ≥1.8x TT-served-leaf byte reduction vs bf16 cores
  decode_driver  Serving-runtime lane — python-loop vs fused-scan decode
            driver (token parity + tok/s, dense and TT weights) and
            continuous batching vs padded lockstep on a heterogeneous
            request mix
  serve_load  Front-door lane — N router replicas + asyncio SSE server
            under a seeded closed-loop request storm (req/s, p50/p99
            latency, slot occupancy; token parity vs isolated runs)
  chaos     Fault-tolerance lane — seeded FaultPlan injects a replica
            crash, a slow-chunk straggler, a NaN-poisoned request, and a
            corrupt checkpoint; gates zero hung tickets, typed errors,
            survivor parity vs isolated runs, and full live-replica
            recovery

``--fast`` propagates to every benchmark that accepts a ``fast=`` kwarg
(smaller sweeps, single method) — the CI smoke lane that catches
benchmark-script rot without paying full benchmark wall-clock.

Headline numbers additionally persist as ``BENCH_<lane>.json`` at the repo
root (``benchmarks/record.py``) so the perf trajectory is tracked across
PRs, not just printed: ``decode_driver`` → BENCH_decode.json, ``tt_serve``/
``tt_families`` → BENCH_tt_serve.json, ``tt_quant`` (and the quantized leg
of ``tt_families``) → BENCH_tt_quant.json, ``serve_load`` →
BENCH_serve_load.json, ``chaos`` → BENCH_chaos.json.
"""

from __future__ import annotations

import argparse
import inspect
import os
import sys
import time
import traceback

try:                                   # installed package (pip install -e .)
    import repro                       # noqa: F401
except ModuleNotFoundError:            # bare checkout: bootstrap src/
    sys.path.insert(
        0, os.path.join(os.path.dirname(__file__), "..", "src")
    )


def bench_table1(fast: bool = False):
    from benchmarks import table1_compression
    table1_compression.run(fast=fast)


def bench_table3(fast: bool = False):
    from benchmarks import table3_phases
    table3_phases.run(max_tensors=4 if fast else 12)


def bench_batched(fast: bool = False):
    from benchmarks import batched_compression
    batched_compression.run(fast=fast)


def bench_comm(fast: bool = False):
    from benchmarks import table_comm
    table_comm.run(n_pods=2 if fast else 4)


def bench_roofline():
    from benchmarks import roofline_bench
    roofline_bench.run()


def bench_kernels(fast: bool = False):
    from benchmarks import kernel_bench
    kernel_bench.run(fast=fast)


def bench_tt_serve(fast: bool = False):
    from benchmarks import tt_serve
    tt_serve.run(fast=fast)


def bench_tt_families(fast: bool = False):
    from benchmarks import tt_serve
    tt_serve.run_families(fast=fast)
    # the quantized family rides the coverage lane: one reduced config
    # through int8 cores, gating agreement + leaf-byte reduction
    tt_serve.run_quant(fast=fast)


def bench_tt_quant(fast: bool = False):
    from benchmarks import tt_serve
    tt_serve.run_quant(fast=fast)


def bench_decode_driver(fast: bool = False):
    from benchmarks import decode_driver
    decode_driver.run(fast=fast)


def bench_serve_load(fast: bool = False):
    from benchmarks import serve_load
    serve_load.run(fast=fast)


def bench_chaos(fast: bool = False):
    from benchmarks import chaos_serve
    chaos_serve.run(fast=fast)


ALL = {
    "table1": bench_table1,
    "table3": bench_table3,
    "batched": bench_batched,
    "comm": bench_comm,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
    "tt_serve": bench_tt_serve,
    "tt_families": bench_tt_families,
    "tt_quant": bench_tt_quant,
    "decode_driver": bench_decode_driver,
    "serve_load": bench_serve_load,
    "chaos": bench_chaos,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke mode: shrunken sweeps, same code paths")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        raise SystemExit(
            f"unknown benchmark(s): {','.join(unknown)} "
            f"(choose from: {','.join(ALL)})"
        )

    failures = []
    for name in names:
        print(f"\n{'=' * 72}\n== benchmark: {name}"
              + (" (fast)" if args.fast else "")
              + f"\n{'=' * 72}", flush=True)
        t0 = time.time()
        fn = ALL[name]
        try:
            if "fast" in inspect.signature(fn).parameters:
                fn(fast=args.fast)
            else:
                fn()
            print(f"== {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
