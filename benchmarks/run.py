"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table3,comm,roofline]

  table1    Paper Table I   — TD-method comparison on ResNet-32 params
  table3    Paper Table III — TTD phase breakdown, baseline vs TT-Edge
  comm      Paper Fig. 1    — cross-pod TT-compressed sync payload
  roofline  §Roofline       — per-cell roofline table from the dry-run
  kernels   Pallas kernel block-shape sweeps vs ref oracles (quick)
"""

from __future__ import annotations

import argparse
import time
import traceback


def bench_table1():
    from benchmarks import table1_compression
    table1_compression.run()


def bench_table3():
    from benchmarks import table3_phases
    table3_phases.run()


def bench_comm():
    from benchmarks import table_comm
    table_comm.run()


def bench_roofline():
    from benchmarks import roofline_bench
    roofline_bench.run()


def bench_kernels():
    from benchmarks import kernel_bench
    kernel_bench.run()


ALL = {
    "table1": bench_table1,
    "table3": bench_table3,
    "comm": bench_comm,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)

    failures = []
    for name in names:
        print(f"\n{'=' * 72}\n== benchmark: {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            ALL[name]()
            print(f"== {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
