"""Paper Table III — TTD phase time breakdown, baseline vs TT-Edge analogue.

The paper instruments TTD of ResNet-32 into five phases and compares the
GEMM-only baseline processor against TT-Edge:

  phase              baseline(ms)  tt-edge(ms)  speedup
  HBD                5626.42       2743.80      2.05×
  QR Decomp.         1554.66       1554.66      1.00×   (unaccelerated)
  Sort. & Trunc.     312.56        31.37        9.96×
  Update SVD In.     46.65         46.65        1.00×
  Reshape & etc      189.24        189.24       1.00×
  Total              7729.52       4566.71      1.70×

Here the two "processors" are two schedules of the same arithmetic:
  baseline  — paper-faithful Algorithm 2: unblocked HBD (one reflector at a
              time, rank-1 updates = the 16×16-GEMM-array path);
  tt-edge   — the TPU-native analogue of the TTD-Engine: panel/WY-blocked
              HBD (Householder vectors resident in fast memory, trailing
              update as large MXU-shaped GEMMs) + fused sort/truncate.
Wall-clock is CPU (this container), so absolute times differ from the
paper's 100 MHz FPGA; the *structure* (HBD-dominant, phase ratios) is the
reproduction target.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocked as _blocked
from repro.core import hbd as _hbd
from repro.core import truncation as _trunc
from repro.core.svd import sorting_basis
from benchmarks.workload_resnet32 import conv_stack, resnet32_params

PHASES = ("HBD", "QR Decomp.", "Sort. & Trunc.", "Update SVD In.",
          "Reshape & etc")


def _block(x):
    jax.block_until_ready(x)
    return x


def _phase_timed_ttd(w: np.ndarray, eps: float, impl: str,
                     times: Dict[str, float]) -> None:
    """One TT-SVD sweep over tensor ``w`` accumulating per-phase seconds.

    impl: "unblocked" (baseline) | "blocked" (tt-edge analogue).
    """
    t0 = time.perf_counter()
    shape = w.shape
    d = w.ndim
    frob = float(np.linalg.norm(w))
    delta = float(_trunc.delta_threshold(eps, d, frob))
    ranks = [1]
    w_temp = w
    times["Reshape & etc"] += time.perf_counter() - t0

    for k in range(d - 1):
        t0 = time.perf_counter()
        rows = ranks[-1] * shape[k]
        mat = jnp.asarray(w_temp.reshape(rows, -1), jnp.float32)
        transpose = mat.shape[0] < mat.shape[1]
        a = _block(mat.T if transpose else mat)
        times["Reshape & etc"] += time.perf_counter() - t0

        # ---- phase 1: HBD -------------------------------------------------
        t0 = time.perf_counter()
        if impl == "blocked":
            u_b, b, v_bt = _blocked.blocked_bidiagonalize(a, panel=32)
        else:
            u_b, b, v_bt = _hbd.householder_bidiagonalize(a)
        _block(b)
        times["HBD"] += time.perf_counter() - t0

        # ---- phase 2: QR-based diagonalization (unaccelerated) ------------
        t0 = time.perf_counter()
        n = a.shape[1]
        q, s, pt = jnp.linalg.svd(b[:n, :n], full_matrices=False)
        u = u_b[:, :n] @ q
        vt = pt @ v_bt
        _block(vt)
        times["QR Decomp."] += time.perf_counter() - t0

        # ---- sorting + δ-truncation ---------------------------------------
        t0 = time.perf_counter()
        u, s, vt = sorting_basis(u, s, vt)
        _block(s)
        s_np = np.asarray(s)
        r = _trunc.truncation_rank(s_np, delta)
        times["Sort. & Trunc."] += time.perf_counter() - t0

        if transpose:
            u, vt = vt.T, u.T
        u_np, s_np, vt_np = (np.asarray(u)[:, :r], s_np[:r],
                             np.asarray(vt)[:r, :])

        # ---- update SVD input: W_temp = Σ_t V_t^T -------------------------
        t0 = time.perf_counter()
        w_temp = s_np[:, None] * vt_np
        times["Update SVD In."] += time.perf_counter() - t0

        t0 = time.perf_counter()
        ranks.append(r)
        times["Reshape & etc"] += time.perf_counter() - t0


def run(eps: float = 0.22, seed: int = 0, max_tensors: int = 12,
        verbose: bool = True) -> Dict:
    """Phase breakdown over the largest ResNet-32 conv stack tensors."""
    params = resnet32_params(seed=seed)
    stack = sorted(conv_stack(params), key=lambda kv: -kv[1].size)
    tensors = [w for _, w in stack[:max_tensors]]

    results = {}
    for impl, label in (("unblocked", "baseline"), ("blocked", "tt-edge")):
        # pass 1 warms every jit cache entry (TT-SVD shapes are
        # data-deterministic, so pass 2 hits only compiled code); pass 2 is
        # the measured execution time — the analogue of steady-state
        # hardware throughput, not compile latency.
        warm = {p: 0.0 for p in PHASES}
        for w in tensors:
            _phase_timed_ttd(w, eps, impl, warm)
        times = {p: 0.0 for p in PHASES}
        for w in tensors:
            _phase_timed_ttd(w, eps, impl, times)
        times["Total"] = sum(times[p] for p in PHASES)
        results[label] = times

    paper = {"HBD": (5626.42, 2743.80), "QR Decomp.": (1554.66, 1554.66),
             "Sort. & Trunc.": (312.56, 31.37),
             "Update SVD In.": (46.65, 46.65),
             "Reshape & etc": (189.24, 189.24),
             "Total": (7729.52, 4566.71)}
    if verbose:
        print(f"# Table III analogue ({len(tensors)} largest conv tensors, "
              f"ε={eps}; CPU wall-clock)")
        print("phase,baseline_ms,ttedge_ms,speedup,paper_speedup")
        for p in PHASES + ("Total",):
            b = results["baseline"][p] * 1e3
            t = results["tt-edge"][p] * 1e3
            pb, pt_ = paper[p]
            print(f"{p},{b:.1f},{t:.1f},{b / max(t, 1e-9):.2f},"
                  f"{pb / pt_:.2f}")
        hb = results["baseline"]["HBD"] / results["baseline"]["Total"]
        print(f"# HBD share of baseline total: {hb:.1%} (paper: 72.8%)")
    return results


if __name__ == "__main__":
    run()
