"""Sustained-load benchmark: the wired front door under a request storm.

End-to-end over real HTTP: N engine replicas behind the load-aware router
and the asyncio SSE server, hammered by a seeded closed-loop client pool.
Every request streams (SSE), carries its own sampling params
(greedy/temperature/top-k mix) and its own seed, and is checked
token-for-token against an isolated ``generate`` run — throughput that
breaks staggered == isolated does not count.

Measures, per replica tier (N=1 and N=2):

  * aggregate req/s and tok/s over the full trace (closed loop,
    ``concurrency`` in-flight clients);
  * per-request latency p50/p99 (ms, first-byte-to-done as seen by the
    client);
  * mean slot occupancy across replicas (from ``GET /stats`` — useful
    slot-steps / total slot-steps).

Asserts: full token parity at every tier, nonzero occupancy, and — on
multicore hosts only (``os.cpu_count() >= 2``; replica chunks can't
overlap on one core) — N=2 aggregate req/s >= 1.5x N=1.

Results land in ``BENCH_serve_load.json`` (see benchmarks/record.py).
"""

from __future__ import annotations

import http.client
import json
import os
import threading
import time

import numpy as np


def _trace(cfg, n, rng):
    """Seeded heterogeneous request trace with a per-request sampling mix:
    a third greedy, a third temperature-only, a third temperature+top-k —
    the per-slot sampling params ride the wire and must round-trip."""
    out = []
    for i in range(n):
        plen = 2 + int(rng.integers(0, 5))
        gen = 3 + int(rng.integers(0, 6))
        req = {"prompt": rng.integers(0, cfg.vocab_size, (plen,))
               .tolist(), "gen": gen, "seed": i, "stream": True}
        if i % 3 == 1:
            req["temperature"] = 0.9
        elif i % 3 == 2:
            req["temperature"] = 1.1
            req["top_k"] = 32
        out.append(req)
    return out


def _isolated(model, params, trace):
    """The parity oracle: every request run alone through the fused
    driver (same seed, same sampling params)."""
    from repro.launch.engine import generate

    expected = []
    for req in trace:
        out = generate(
            model, params, np.asarray(req["prompt"], np.int32)[None],
            req["gen"], driver="fused", seed=req["seed"],
            temperature=req.get("temperature", 0.0),
            top_k=req.get("top_k"),
        )
        expected.append(out["gen"][0].tolist())
    return expected


def _sse_request(port, req, timeout=600):
    """POST one streaming request; returns (tokens, latency_s).  The SSE
    deltas are reassembled and cross-checked against the ``done`` event's
    full token list — a streaming front door that drops or reorders
    chunks fails here, not silently."""
    t0 = time.monotonic()
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    conn.request("POST", "/v1/generate", json.dumps(req),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    if resp.status != 200:
        body = resp.read()
        conn.close()
        raise RuntimeError(f"HTTP {resp.status}: {body[:200]!r}")
    raw = resp.read().decode()
    conn.close()
    latency = time.monotonic() - t0
    deltas, done = [], None
    for block in raw.strip().split("\n\n"):
        lines = block.split("\n")
        event = [ln[7:] for ln in lines if ln.startswith("event: ")]
        data = [ln[6:] for ln in lines if ln.startswith("data: ")]
        if not data:
            continue
        payload = json.loads(data[0])
        if event and event[0] == "done":
            done = payload
        elif event and event[0] == "error":
            raise RuntimeError(f"stream error: {payload}")
        else:
            deltas.extend(payload["tokens"])
    if done is None or deltas != done["tokens"]:
        raise RuntimeError(
            f"SSE deltas {deltas} != done tokens "
            f"{None if done is None else done['tokens']}")
    return done["tokens"], latency


def _fire(port, trace, concurrency):
    """Closed-loop client pool: ``concurrency`` threads drain the trace.
    Returns (wall_s, results[i] -> tokens, latencies)."""
    results = [None] * len(trace)
    latencies = [0.0] * len(trace)
    errors = []
    it = iter(range(len(trace)))
    lock = threading.Lock()

    def worker():
        while True:
            with lock:
                i = next(it, None)
            if i is None:
                return
            try:
                results[i], latencies[i] = _sse_request(port, trace[i])
            except Exception as e:
                errors.append((i, repr(e)))
                return

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(concurrency)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    if errors:
        raise RuntimeError(f"client errors: {errors[:3]}")
    return wall, results, latencies


def _stats(port):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    conn.request("GET", "/stats")
    out = json.loads(conn.getresponse().read())
    conn.close()
    return out


def _run_tier(model, params, trace, expected, replicas, slots, chunk_steps,
              concurrency):
    from repro.launch.engine import Engine
    from repro.launch.router import Router
    from repro.launch.server import serve_in_thread

    engines = [Engine(model, params, slots=slots, max_len=32,
                      chunk_steps=chunk_steps)
               for _ in range(replicas)]
    router = Router(engines, queue_depth=max(concurrency, 2 * slots))
    server, shutdown = serve_in_thread(router)
    try:
        # warmup pass: compiles every chunk length / admission shape the
        # trace will hit, untimed (results discarded); then best-of-2
        # timed passes (closed-loop client jitter, not engine speed, is
        # the noise source on shared CI hosts)
        _fire(server.port, trace, concurrency)
        wall, results, lats = _fire(server.port, trace, concurrency)
        wall2, results2, lats2 = _fire(server.port, trace, concurrency)
        if wall2 < wall:
            wall, results, lats = wall2, results2, lats2
        stats = _stats(server.port)
    finally:
        shutdown()
    parity = all(r == e for r, e in zip(results, expected))
    occ = [r["occupancy"] for r in stats["replicas"]]
    lat_ms = np.asarray(lats) * 1e3
    total_toks = sum(len(r) for r in results)
    row = {
        "replicas": replicas,
        "requests": len(trace),
        "wall_s": round(wall, 3),
        "req_per_s": round(len(trace) / max(wall, 1e-9), 3),
        "tok_per_s": round(total_toks / max(wall, 1e-9), 1),
        "latency_p50_ms": round(float(np.percentile(lat_ms, 50)), 1),
        "latency_p99_ms": round(float(np.percentile(lat_ms, 99)), 1),
        "occupancy": [round(o, 4) for o in occ],
        "token_parity": bool(parity),
    }
    print(f"  N={replicas}: {row['req_per_s']:.2f} req/s  "
          f"{row['tok_per_s']:.0f} tok/s  p50 {row['latency_p50_ms']:.0f}ms  "
          f"p99 {row['latency_p99_ms']:.0f}ms  occupancy "
          f"{[f'{o:.0%}' for o in occ]}  parity={parity}")
    return row


def run(fast: bool = False, arch: str = "qwen1.5-0.5b"):
    import jax

    from benchmarks.record import write_bench
    from repro.configs import get_config
    from repro.models.registry import build

    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_req = 10 if fast else 24
    slots, chunk_steps = 2, 4
    concurrency = 6
    trace = _trace(cfg, n_req, rng)
    print(f"\nsustained load ({arch} reduced): {n_req} streaming requests, "
          f"concurrency={concurrency}, {slots} slots x chunk={chunk_steps}, "
          f"per-request sampling mix")
    expected = _isolated(model, params, trace)

    tiers = [_run_tier(model, params, trace, expected, n, slots,
                       chunk_steps, concurrency)
             for n in (1, 2)]
    speedup = tiers[1]["req_per_s"] / max(tiers[0]["req_per_s"], 1e-9)
    cores = os.cpu_count() or 1
    print(f"  N=2 vs N=1: {speedup:.2f}x aggregate req/s "
          f"({cores} host cores)")
    results = {
        "arch": arch,
        "requests": n_req,
        "concurrency": concurrency,
        "slots": slots,
        "chunk_steps": chunk_steps,
        "host_cores": cores,
        "tiers": tiers,
        "replica_speedup": round(speedup, 3),
    }
    for row in tiers:
        assert row["token_parity"], (
            f"N={row['replicas']}: routed tokens diverged from isolated "
            f"runs — throughput without parity does not count")
        assert max(row["occupancy"]) > 0.0, row
    if cores >= 2:
        # replica chunks only overlap when there are cores to overlap on;
        # a single-core host interleaves them (correctness holds, wall
        # clock cannot improve), so the scaling gate is multicore-only
        assert speedup >= 1.5, (
            f"2 replicas gave {speedup:.2f}x aggregate req/s on "
            f"{cores} cores (expected >= 1.5x)")
    write_bench("serve_load", results)
    return results


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
