"""Pallas kernel micro-bench: shape sweeps vs ref oracles (interpret mode).

Interpret-mode wall-clock is NOT TPU performance — correctness + the chosen
block shapes are the report here; kernel perf on hardware is governed by the
BlockSpec tiling documented per kernel (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_update.ops import block_wy_update
from repro.kernels.block_update.ref import wy_update_ref
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.frob_truncate.ops import delta_truncate
from repro.kernels.frob_truncate.ref import frob_truncate_ref
from repro.kernels.householder.ops import (
    panel_factor, panel_factor_batched, build_t,
)
from repro.kernels.householder.ref import panel_factor_ref
from repro.kernels.singular_sort.ops import sort_singular_values
from repro.kernels.singular_sort.ref import sort_desc_ref


def _maxerr(a, b) -> float:
    return float(jnp.max(jnp.abs(a - b)))


def run(verbose: bool = True, fast: bool = False) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # WY trailing update — the TTD-Engine GEMM-reuse analogue
    wy_shapes = [(256, 192, 32)] if fast else [(256, 192, 32), (384, 256, 64)]
    for (m, n, b) in wy_shapes:
        a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        vs, taus, _ = panel_factor_ref(
            jnp.asarray(rng.standard_normal((m, b)), jnp.float32))
        t = build_t(vs, taus)
        t0 = time.perf_counter()
        out = jax.block_until_ready(block_wy_update(a, vs, t, interpret=True))
        dt = time.perf_counter() - t0
        err = _maxerr(out, wy_update_ref(a, vs, t))
        rows.append({"kernel": "block_update", "shape": f"{m}x{n}b{b}",
                     "max_err": err, "wall_s": dt})

    # Householder panel factorization
    panel_shapes = [(256, 32)] if fast else [(256, 32), (512, 64)]
    for (m, b) in panel_shapes:
        ap = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
        t0 = time.perf_counter()
        vs, taus, r_ = jax.block_until_ready(panel_factor(ap, interpret=True))
        dt = time.perf_counter() - t0
        vr, tr, rr_ = panel_factor_ref(ap)
        err = max(_maxerr(vs, vr), _maxerr(taus, tr), _maxerr(r_, rr_))
        rows.append({"kernel": "householder_panel", "shape": f"{m}x{b}",
                     "max_err": err, "wall_s": dt})

    # batched panel factorization: one launch, batch on the grid — the
    # dispatch-amortization path the compression planner rides
    bsz, m, b = (4, 128, 16) if fast else (8, 256, 32)
    aps = jnp.asarray(rng.standard_normal((bsz, m, b)), jnp.float32)
    t0 = time.perf_counter()
    vb, tb, rb = jax.block_until_ready(
        panel_factor_batched(aps, interpret=True))
    dt = time.perf_counter() - t0
    err = 0.0
    for k in range(bsz):
        vr, tr, rr_ = panel_factor_ref(aps[k])
        err = max(err, _maxerr(vb[k], vr), _maxerr(tb[k], tr),
                  _maxerr(rb[k], rr_))
    rows.append({"kernel": "householder_panel_batched",
                 "shape": f"{bsz}x{m}x{b}", "max_err": err, "wall_s": dt})

    # bitonic singular-value sort
    for n in (128, 500):
        s = jnp.asarray(np.abs(rng.standard_normal(n)), jnp.float32)
        t0 = time.perf_counter()
        ss, ind = jax.block_until_ready(
            sort_singular_values(s, interpret=True))
        dt = time.perf_counter() - t0
        sr, ir = sort_desc_ref(s)
        err = _maxerr(ss, sr)
        rows.append({"kernel": "singular_sort", "shape": f"{n}",
                     "max_err": err, "wall_s": dt})

    # δ-truncation reverse-Frobenius scan
    for n in (128, 512):
        s = jnp.sort(jnp.asarray(
            np.abs(rng.standard_normal(n)), jnp.float32))[::-1]
        delta = float(0.3 * np.linalg.norm(np.asarray(s)))
        t0 = time.perf_counter()
        tail, rank = jax.block_until_ready(
            delta_truncate(s, delta, interpret=True))
        dt = time.perf_counter() - t0
        tail_r, rank_r = frob_truncate_ref(s, delta)
        err = max(_maxerr(tail, tail_r), float(jnp.abs(rank - rank_r)))
        rows.append({"kernel": "frob_truncate", "shape": f"{n}",
                     "max_err": err, "wall_s": dt})

    # flash attention (GQA + causal)
    b_, s_, hq, hkv, d = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((b_, s_, hq, d)), jnp.float32) * 0.1
    k = jnp.asarray(rng.standard_normal((b_, s_, hkv, d)), jnp.float32) * 0.1
    v = jnp.asarray(rng.standard_normal((b_, s_, hkv, d)), jnp.float32) * 0.1
    t0 = time.perf_counter()
    out = jax.block_until_ready(mha_flash(q, k, v, causal=True,
                                          interpret=True))
    dt = time.perf_counter() - t0
    kx = jnp.repeat(k, hq // hkv, axis=2)
    vx = jnp.repeat(v, hq // hkv, axis=2)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b_ * hq, s_, d),
        kx.transpose(0, 2, 1, 3).reshape(b_ * hq, s_, d),
        vx.transpose(0, 2, 1, 3).reshape(b_ * hq, s_, d),
        causal=True,
    ).reshape(b_, hq, s_, d).transpose(0, 2, 1, 3)
    err = _maxerr(out, ref)
    rows.append({"kernel": "flash_attention", "shape": f"s{s_}h{hq}/{hkv}",
                 "max_err": err, "wall_s": dt})

    if verbose:
        print("kernel,shape,max_abs_err,interpret_wall_s")
        for r in rows:
            print(f"{r['kernel']},{r['shape']},{r['max_err']:.2e},"
                  f"{r['wall_s']:.2f}")
        bad = [r for r in rows if r["max_err"] > 5e-3]
        print(f"# {len(rows)} kernel cells, {len(bad)} above tolerance")
    return rows


if __name__ == "__main__":
    run()
