"""Chaos lane: deterministic fault injection against the serving plane.

The fault-tolerance layer (router supervision + failover, numeric
quarantine, checkpoint integrity) is only real if it survives actual
faults — so this lane injects them, at seeded deterministic points
(``runtime/fault_tolerance.FaultPlan``), and holds the plane to three
invariants:

  * **nothing hangs** — every request reaches a terminal state within the
    drain timeout: tokens, or a TYPED error (``ReplicaLost`` /
    ``NumericFault`` / ``DeadlineExpired`` / ...);
  * **survivor parity** — every request that completes returns tokens
    bit-exact vs an isolated ``generate`` run (failover re-decodes only
    never-admitted requests, so parity must hold through a crash);
  * **full recovery** — after the injected crash the router restarts the
    replica and returns to full live capacity, and a retry pass over the
    ``replica_lost`` requests then succeeds (except the NaN-poisoned one,
    which must fail ``NumericFault`` again — poison is not retryable).

Injected per run: one replica crash mid-trace (worker raises inside the
chunk loop), one slow-chunk straggler (trips the watchdog into
``suspect`` and recovers), one NaN-poisoned request (magic poison token
in the prompt → non-finite logits → quarantine), and a corrupt/truncated
checkpoint leg (sha256 verification must name the bad leaf; stale tmp
dirs must be cleaned).

Results land in ``BENCH_chaos.json``: injected/fired fault counts,
outcome histogram, recovery time, survivor parity, retry outcomes, and
the checkpoint-integrity checklist.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import threading
import time

import numpy as np


# ---------------------------------------------------------------------------
# NaN injection: a model whose decode emits non-finite logits for any slot
# whose current input token is the magic poison token.  Everything else —
# engine, router, guard — is the production path.
# ---------------------------------------------------------------------------

def poison_token(cfg) -> int:
    return cfg.vocab_size - 1


def poisoned_model(model):
    import jax.numpy as jnp

    tok = poison_token(model.cfg)
    base = model.decode_step

    def decode(p, c, t):
        logits, cache = base(p, c, t)
        hit = jnp.any(t == tok, axis=-1)
        return jnp.where(hit[:, None], jnp.asarray(np.nan, logits.dtype),
                         logits), cache

    return dataclasses.replace(model, decode_step=decode)


def _trace(cfg, n, rng, poison):
    out = []
    for i in range(n):
        plen = 2 + int(rng.integers(0, 4))
        prompt = rng.integers(0, cfg.vocab_size - 1,
                              (plen,)).astype(np.int32)
        if i in poison:
            prompt[-1] = poison_token(cfg)
        out.append({"prompt": prompt, "gen": 4 + int(rng.integers(0, 4)),
                    "seed": i})
    return out


def _isolated(model, params, trace, poison):
    """Parity oracle for non-poisoned requests (poisoned ones have no
    meaningful tokens — they must be quarantined, not compared)."""
    from repro.launch.engine import generate

    expected = {}
    for i, req in enumerate(trace):
        if i in poison:
            continue
        out = generate(model, params, req["prompt"][None], req["gen"],
                       driver="fused", seed=req["seed"])
        expected[i] = out["gen"][0].tolist()
    return expected


class _HealthSampler(threading.Thread):
    """Poll replica states during the storm: records when capacity first
    degrades, when it comes back, and whether the straggler was caught
    in ``suspect``."""

    def __init__(self, router):
        super().__init__(name="health-sampler", daemon=True)
        self.router = router
        self.total = len(router.replicas)
        self.stop = threading.Event()
        self.t_degraded = None
        self.t_recovered = None
        self.min_live = self.total
        self.suspect_seen = False

    def run(self):
        from repro.launch.router import SUSPECT

        while not self.stop.wait(0.005):
            st = self.router.stats()
            live = st["live_replicas"]
            self.min_live = min(self.min_live, live)
            if any(r["state"] == SUSPECT for r in st["replicas"]):
                self.suspect_seen = True
            now = time.monotonic()
            if live < self.total and self.t_degraded is None:
                self.t_degraded = now
            if (self.t_degraded is not None and live == self.total
                    and self.t_recovered is None):
                self.t_recovered = now


def _drain(router, tickets, timeout_s):
    """Resolve every ticket to (kind, payload); a ticket that does not
    terminate within the budget is a HANG — the one thing this lane
    exists to rule out."""
    from repro.launch.router import (DeadlineExpired, NumericFault,
                                     ReplicaLost, RequestCancelled)

    outcomes = {}
    hung = []
    deadline = time.monotonic() + timeout_s
    for i, t in tickets.items():
        left = max(0.5, deadline - time.monotonic())
        try:
            outcomes[i] = ("done", t.result(timeout=left))
        except ReplicaLost as e:
            outcomes[i] = ("replica_lost", str(e))
        except NumericFault as e:
            outcomes[i] = ("poisoned", str(e))
        except DeadlineExpired as e:
            outcomes[i] = ("expired", str(e))
        except RequestCancelled as e:
            outcomes[i] = ("cancelled", str(e))
        except Exception as e:
            if e.__class__.__name__ == "Empty":      # queue.Empty: no event
                hung.append(i)
                outcomes[i] = ("HUNG", None)
            else:
                outcomes[i] = ("error", f"{type(e).__name__}: {e}")
    return outcomes, hung


# ---------------------------------------------------------------------------
# checkpoint-integrity leg
# ---------------------------------------------------------------------------

def _checkpoint_leg() -> dict:
    from repro.checkpoint.checkpoint import (CheckpointCorrupt,
                                             CheckpointManager)

    rng = np.random.default_rng(0)
    state = {"w": rng.standard_normal((16, 16)).astype(np.float32),
             "b": rng.standard_normal((8,)).astype(np.float32)}
    out = {}
    root = tempfile.mkdtemp(prefix="chaos_ckpt_")
    try:
        cdir = os.path.join(root, "ckpt")
        mgr = CheckpointManager(cdir, async_save=False)
        mgr.save(3, state)
        restored, _ = mgr.restore(state)          # verify=True default
        out["clean_restore"] = bool(
            np.array_equal(np.asarray(restored["w"]), state["w"]))

        # bit-flip: rewrite the shard with one array zeroed — a VALID zip
        # with wrong content, so only the sha256 can catch it
        shard = os.path.join(cdir, "step_000003", "shard_0.npz")
        data = dict(np.load(shard))
        data["w"] = np.zeros_like(data["w"])
        np.savez(shard, **data)
        try:
            mgr.restore(state)
            out["bitflip_caught"] = False
        except CheckpointCorrupt as e:
            out["bitflip_caught"] = "'w'" in str(e)
        # opt-out still loads the (corrupt) shard
        try:
            mgr.restore(state, verify=False)
            out["verify_opt_out"] = True
        except Exception:
            out["verify_opt_out"] = False

        # truncation: chop the archive mid-file
        mgr.save(4, state)
        shard4 = os.path.join(cdir, "step_000004", "shard_0.npz")
        with open(shard4, "rb") as f:
            raw = f.read()
        with open(shard4, "wb") as f:
            f.write(raw[: len(raw) // 2])
        try:
            mgr.restore(state, step=4)
            out["truncation_caught"] = False
        except CheckpointCorrupt:
            out["truncation_caught"] = True

        # stale tmp dir from a crashed save is cleaned on open
        stale = os.path.join(cdir, "step_000009.tmp")
        os.makedirs(stale)
        mgr2 = CheckpointManager(cdir)
        out["stale_tmp_cleaned"] = (stale in mgr2.cleaned_tmp
                                    and not os.path.exists(stale))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


# ---------------------------------------------------------------------------
# the lane
# ---------------------------------------------------------------------------

def run(fast: bool = False, arch: str = "qwen1.5-0.5b", seed: int = 0):
    import jax

    from benchmarks.record import write_bench
    from repro.configs import get_config
    from repro.launch.engine import Engine
    from repro.launch.router import Router
    from repro.models.registry import build
    from repro.runtime.fault_tolerance import FaultPlan, RestartPolicy

    cfg = get_config(arch).reduced()
    model = poisoned_model(build(cfg))
    params = model.init(jax.random.PRNGKey(0))

    replicas = 3
    n_req = 8 if fast else 20
    plan = FaultPlan.seeded(seed, replicas=replicas, requests=n_req,
                            crashes=1, stalls=1, poisons=1, stall_s=1.0,
                            span=3 if fast else 5)
    print(f"\nchaos ({arch} reduced): {n_req} requests over {replicas} "
          f"replicas; plan: crash={plan.crash_at} stall={plan.stall_at} "
          f"poison={plan.poison}")

    rng = np.random.default_rng(seed)
    trace = _trace(cfg, n_req, rng, set(plan.poison))
    expected = _isolated(model, params, trace, set(plan.poison))

    def mk_engine(_old=None):
        return Engine(model, params, slots=2, max_len=32, chunk_steps=3)

    router = Router(
        [mk_engine() for _ in range(replicas)], queue_depth=12,
        watchdog_s=0.4,
        restart_policy=RestartPolicy(max_restarts=3, backoff_s=0.05,
                                     max_backoff_s=0.5),
        engine_factory=mk_engine, supervise_interval=0.02,
    )
    for i, rep in enumerate(router.replicas):
        rep.fault_hook = plan.hook_for(i)

    sampler = _HealthSampler(router)
    router.start()
    sampler.start()
    t0 = time.monotonic()
    tickets = {i: router.submit(req["prompt"], req["gen"], seed=req["seed"])
               for i, req in enumerate(trace)}
    outcomes, hung = _drain(router, tickets, timeout_s=300)
    # recovery: wait for full live capacity (restart backoff is tiny)
    t_full = time.monotonic() + 30
    while router.live_replicas() < replicas and time.monotonic() < t_full:
        time.sleep(0.02)
    storm_s = time.monotonic() - t0
    sampler.stop.set()
    sampler.join(timeout=5)

    # retry pass: replica_lost is RETRYABLE (at-most-once delivery means
    # nothing was re-decoded) — after recovery a retry must succeed, and
    # the poisoned request must be rejected AGAIN (poison is permanent)
    retry = {}
    for i, (kind, _) in outcomes.items():
        if kind != "replica_lost":
            continue
        req = trace[i]
        t = router.submit(req["prompt"], req["gen"], seed=req["seed"])
        retry.update({i: r for i, r in
                      _drain(router, {i: t}, timeout_s=120)[0].items()})
    stats = router.stats()
    router.close()

    hist = {}
    for kind, _ in outcomes.values():
        hist[kind] = hist.get(kind, 0) + 1
    parity_fail = [i for i, (k, c) in outcomes.items()
                   if k == "done" and c.tokens.tolist() != expected[i]]
    retry_parity_fail = [i for i, (k, c) in retry.items()
                         if k == "done" and c.tokens.tolist() != expected[i]]
    recovery_s = (None if sampler.t_recovered is None or
                  sampler.t_degraded is None
                  else sampler.t_recovered - sampler.t_degraded)
    ckpt = _checkpoint_leg() if plan.corrupt_checkpoint else {}

    results = {
        "arch": arch,
        "seed": seed,
        "requests": n_req,
        "replicas": replicas,
        "injected": plan.counts(),
        "fired": plan.fired(),
        "outcomes": hist,
        "hung": len(hung),
        "survivor_parity": not parity_fail,
        "retry_outcomes": {str(i): k for i, (k, _) in retry.items()},
        "retry_parity": not retry_parity_fail,
        "recovery_s": None if recovery_s is None else round(recovery_s, 3),
        "min_live_replicas": sampler.min_live,
        "suspect_seen": sampler.suspect_seen,
        "live_replicas_final": stats["live_replicas"],
        "restarts": [r["restarts"] for r in stats["replicas"]],
        "storm_s": round(storm_s, 3),
        "checkpoint": ckpt,
    }
    print(f"  outcomes: {hist}  hung={len(hung)}  "
          f"recovery={results['recovery_s']}s  "
          f"suspect_seen={sampler.suspect_seen}  "
          f"restarts={results['restarts']}")
    print(f"  retry: {results['retry_outcomes']}  checkpoint: {ckpt}")

    # -- the gates -----------------------------------------------------------
    assert not hung, f"HUNG tickets: {hung} — fault tolerance failed"
    allowed = {"done", "replica_lost", "poisoned"}
    assert set(hist) <= allowed, f"untyped outcomes: {hist}"
    assert not parity_fail, (
        f"survivors diverged from isolated runs: {parity_fail}")
    assert plan.fired()["crashes"] == len(plan.crash_at), (
        "planned crash never fired — the lane tested nothing")
    assert stats["live_replicas"] == replicas, (
        f"router did not recover: {stats['live_replicas']}/{replicas} live")
    assert sampler.min_live < replicas, (
        "capacity never degraded — crash path untested")
    assert sampler.suspect_seen, (
        "straggler never tripped the watchdog into suspect")
    # retries: every replica_lost request succeeds on retry, except a
    # poisoned one which must be quarantined again
    for i, (kind, _) in retry.items():
        want = "poisoned" if i in plan.poison else "done"
        assert kind == want, f"retry of request {i}: {kind} != {want}"
    assert not retry_parity_fail, retry_parity_fail
    if ckpt:
        assert all(ckpt.values()), f"checkpoint integrity leg failed: {ckpt}"

    write_bench("chaos", results)
    return results


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
