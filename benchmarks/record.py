"""Machine-readable benchmark persistence: ``BENCH_<name>.json``.

Benchmarks historically printed their tables and exited — nothing survived
the run, so perf trajectories across PRs lived in commit messages.  Lanes
now ALSO dump their headline numbers (tok/s, bytes, parity flags) as one
flat JSON file per lane at the repo root, overwritten on each run:

    BENCH_decode.json      benchmarks/decode_driver.py
    BENCH_tt_serve.json    benchmarks/tt_serve.py

Set ``BENCH_DIR`` to redirect the output directory (CI artifacts, scratch
runs).  Files are written atomically (tmp + rename) so a crashed benchmark
never leaves a truncated record behind.
"""

from __future__ import annotations

import json
import os
import tempfile


def bench_dir() -> str:
    env = os.environ.get("BENCH_DIR")
    if env:
        return env
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_path(name: str) -> str:
    return os.path.join(bench_dir(), f"BENCH_{name}.json")


def write_bench(name: str, payload: dict) -> str:
    """Persist one lane's results; returns the path written."""
    path = bench_path(name)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(path), prefix=f".BENCH_{name}.", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, default=float)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    print(f"[bench] results -> {path}")
    return path
