"""§Roofline table — render the dry-run artifacts as the per-cell report.

Reads ``results/dryrun_single_pod.json`` (+ optional multi-pod / hillclimb
files) and prints, per (arch × shape): the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and the
roofline fraction (compute_s / max-term).  This file does NOT lower
anything itself — run ``python -m repro.launch.dryrun --all`` first.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(multi_pod: bool = False) -> List[Dict]:
    name = "dryrun_multi_pod.json" if multi_pod else "dryrun_single_pod.json"
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def render(rows: List[Dict], verbose: bool = True) -> List[Dict]:
    out = []
    for e in rows:
        if e.get("skipped"):
            out.append({"arch": e["arch"], "shape": e["shape"],
                        "status": "SKIP"})
            continue
        if "error" in e:
            out.append({"arch": e["arch"], "shape": e["shape"],
                        "status": "FAIL"})
            continue
        r = e["roofline"]
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append({
            "arch": e["arch"], "shape": e["shape"], "status": "OK",
            "compute_ms": r["compute_s"] * 1e3,
            "memory_ms": r["memory_s"] * 1e3,
            "collective_ms": r["collective_s"] * 1e3,
            "bottleneck": r["bottleneck"],
            "roofline_frac": r["compute_s"] / step if step else 0.0,
            "useful_ratio": e.get("useful_flops_ratio") or 0.0,
            "hbm_fit": e["memory"]["peak_ok"],
        })
    if verbose:
        print("arch,shape,compute_ms,memory_ms,collective_ms,bottleneck,"
              "roofline_frac,useful_flops_ratio,fits_hbm")
        for o in out:
            if o["status"] != "OK":
                print(f"{o['arch']},{o['shape']},{o['status']},,,,,,")
                continue
            print(f"{o['arch']},{o['shape']},{o['compute_ms']:.1f},"
                  f"{o['memory_ms']:.1f},{o['collective_ms']:.1f},"
                  f"{o['bottleneck']},{o['roofline_frac']:.3f},"
                  f"{o['useful_ratio']:.3f},{o['hbm_fit']}")
    return out


def run(verbose: bool = True) -> Dict:
    single = render(load(multi_pod=False), verbose=verbose)
    ok = [o for o in single if o["status"] == "OK"]
    if verbose and ok:
        worst = sorted(ok, key=lambda o: o["roofline_frac"])[:3]
        print("# worst roofline fractions:",
              "; ".join(f"{o['arch']}×{o['shape']}={o['roofline_frac']:.3f}"
                        for o in worst))
    return {"single_pod": single}


if __name__ == "__main__":
    run()
