"""Stochastic sampling in the decode drivers (temperature / top-k / seed).

The sampling contract added to the fused driver:

  * ``temperature=0`` IS the old greedy driver — bit-identical logits and
    tokens, no PRNG math traced;
  * a fixed seed is fully deterministic: same tokens run-to-run, and the
    python one-step-per-token loop is a token-for-token oracle for the
    fused scan under the SAME per-row ``fold_in(key, t)`` streams;
  * keys advance with slot-local progress only, so the continuous-batching
    engine inherits staggered == isolated under sampling (asserted in
    test_decode_driver.py).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import generate
from repro.models.registry import build

FAMILY_ARCHS = [
    "gemma3-1b",              # transformer (dense)
    "seamless-m4t-large-v2",  # encdec
    "mamba2-1.3b",            # ssm
    "recurrentgemma-2b",      # hybrid
    "olmoe-1b-7b",            # moe expert banks
]


def _setup(arch="qwen1.5-0.5b"):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 4), np.int32)
    src = None
    if model.populate_memory is not None:
        src = rng.integers(0, cfg.vocab_size, (2, 5), np.int32)
    return cfg, model, params, prompts, src


def test_temperature_zero_is_exactly_greedy():
    """temperature=0 must reduce to the pre-sampling greedy driver bit for
    bit — tokens AND prompt logits — regardless of the seed."""
    cfg, model, params, prompts, _ = _setup()
    base = generate(model, params, prompts, 6, driver="fused")
    for seed in (0, 7, 123):
        out = generate(model, params, prompts, 6, driver="fused",
                       temperature=0.0, seed=seed)
        np.testing.assert_array_equal(out["gen"], base["gen"])
        np.testing.assert_array_equal(
            np.asarray(out["prompt_logits"]),
            np.asarray(base["prompt_logits"]),
        )


def test_fixed_seed_reproduces_tokens():
    """Same seed → same tokens, run to run; different seeds actually
    sample differently (high temperature, wide vocab — a collision across
    every generated token is beyond astronomically unlikely)."""
    cfg, model, params, prompts, _ = _setup()
    kw = dict(temperature=1.2, top_k=None, seed=42)
    a = generate(model, params, prompts, 8, driver="fused", **kw)
    b = generate(model, params, prompts, 8, driver="fused", **kw)
    np.testing.assert_array_equal(a["gen"], b["gen"])
    c = generate(model, params, prompts, 8, driver="fused",
                 temperature=1.2, seed=43)
    assert not np.array_equal(a["gen"], c["gen"])


def test_rows_sample_independent_streams():
    """Each batch row samples under its own fold_in(key, row) stream: two
    rows with the SAME prompt must not emit the same sampled tokens."""
    cfg, model, params, prompts, _ = _setup()
    same = np.tile(prompts[:1], (2, 1))
    out = generate(model, params, same, 8, driver="fused",
                   temperature=1.2, seed=3)
    assert not np.array_equal(out["gen"][0], out["gen"][1])


def _assert_sampled_parity(arch, temperature=0.8, top_k=50, seed=11):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (2, 4), np.int32)
    src = None
    if model.populate_memory is not None:
        src = rng.integers(0, cfg.vocab_size, (2, 5), np.int32)
    kw = dict(src_tokens=src, temperature=temperature, top_k=top_k,
              seed=seed)
    py = generate(model, params, prompts, 6, driver="python", **kw)
    fu = generate(model, params, prompts, 6, driver="fused", **kw)
    np.testing.assert_array_equal(py["gen"], fu["gen"])


def test_sampled_fused_matches_python_transformer():
    """Fast lane: the python loop is a token-for-token oracle for the
    fused scan under stochastic sampling (same keys, same tokens)."""
    _assert_sampled_parity("qwen1.5-0.5b")


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_sampled_fused_matches_python_families(arch):
    _assert_sampled_parity(arch)


def test_sampling_params_validated_up_front():
    """Junk sampling params fail fast with a clear message, not an opaque
    broadcast error deep inside the jitted scan; a negative temperature
    must never silently sample the inverted distribution."""
    from repro.launch.engine import Engine

    cfg, model, params, prompts, _ = _setup()
    with pytest.raises(ValueError, match="temperature"):
        generate(model, params, prompts, 4, temperature=-0.5)
    with pytest.raises(ValueError, match="top_k"):
        generate(model, params, prompts, 4, temperature=0.8, top_k=0)
    with pytest.raises(ValueError, match="top_k"):
        Engine(model, params, slots=2, max_len=16,
               temperature=0.8, top_k=-3)


def test_generate_rejects_oversized_src():
    """generate() gives the same clear encoder-capacity error submit()
    does, instead of an opaque shape mismatch inside populate_memory."""
    cfg, model, params, prompts, _ = _setup("seamless-m4t-large-v2")
    too_long = np.zeros((2, cfg.frontend_len + 1), np.int32)
    with pytest.raises(ValueError, match="encoder positions"):
        generate(model, params, prompts, 4, src_tokens=too_long)


def test_top_k_filters_the_support():
    """top-k sampling never emits a token outside the top k logits of the
    step that produced it — checked against the python loop's per-step
    logits with k=1 (the sampled token must BE the argmax)."""
    cfg, model, params, prompts, _ = _setup()
    sampled = generate(model, params, prompts, 6, driver="fused",
                       temperature=2.0, top_k=1, seed=9)
    greedy = generate(model, params, prompts, 6, driver="fused")
    np.testing.assert_array_equal(sampled["gen"], greedy["gen"])
