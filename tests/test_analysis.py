"""The invariant linter is itself under test: every rule is proven live by
a fixture that makes it fire (a linter whose rules can't fire is just a
green checkmark), suppression markers narrow it back down, and the real
tree comes up clean — the same contract the CI ``analysis`` lane enforces
with ``python -m repro.analysis.check --strict``.
"""

import functools
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import astlint, programlint
from repro.analysis.base import all_rules, skip_markers
from repro.analysis.check import main as check_main, run_checks

REPO_ROOT = Path(__file__).resolve().parents[1]


def _ids(findings):
    return sorted({f.rule_id for f in findings})


def _mini_repo(tmp_path, files):
    """Materialize {repo-relative path: source} as a fake checkout."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src, encoding="utf-8")
    return tmp_path


# --------------------------------------------------------------------------
# registry / marker plumbing
# --------------------------------------------------------------------------

def test_rule_registry_complete():
    rules = all_rules()
    ast_ids = {r for r in rules if r.startswith("AST")}
    prg_ids = {r for r in rules if r.startswith("PRG")}
    assert len(ast_ids) >= 4 and len(prg_ids) >= 3
    for r in rules.values():
        assert r.invariant and r.guarded_since


def test_skip_marker_parsing():
    src = (
        "x = 1  # lint: skip[AST001]\n"
        "# lint: skip[AST002, PRG001]\n"
        "y = 2\n"
    )
    skips = skip_markers(src)
    assert skips[1] == {"AST001"}
    assert skips[2] == {"AST002", "PRG001"}
    assert skips[3] == {"AST002", "PRG001"}   # comment covers next line


# --------------------------------------------------------------------------
# AST rules: one violating fixture each, plus suppression
# --------------------------------------------------------------------------

def test_ast001_fires_on_bypassed_dispatch(tmp_path):
    root = _mini_repo(tmp_path, {
        "src/repro/models/bad.py": (
            "import jax.numpy as jnp\n"
            "def f(x, w_gate):\n"
            "    return jnp.einsum('nd,de->ne', x, w_gate.astype(x.dtype))\n"
            "def g(x, weights):\n"
            "    return x @ weights\n"
            "def dense_apply(x, w):\n"
            "    return jnp.dot(x, w)\n"   # the dispatch point itself: exempt
        ),
    })
    fs = astlint.run(root, rules={"AST001"})
    assert _ids(fs) == ["AST001"] and len(fs) == 2
    assert {f.line for f in fs} == {3, 5}


def test_ast001_skip_marker_suppresses(tmp_path):
    root = _mini_repo(tmp_path, {
        "src/repro/models/ok.py": (
            "import jax.numpy as jnp\n"
            "def f(x, conv_w):\n"
            "    # lint: skip[AST001] depthwise tap, not a matmul\n"
            "    return jnp.einsum('bwc,wc->bc', x, conv_w)\n"
        ),
    })
    assert astlint.run(root, rules={"AST001"}) == []


def test_ast002_fires_on_clock_and_global_rng(tmp_path):
    root = _mini_repo(tmp_path, {
        "src/repro/kernels/bad.py": (
            "import time\n"
            "import numpy as np\n"
            "def f():\n"
            "    t = time.perf_counter()\n"
            "    x = np.random.uniform(size=3)\n"
            "    rng = np.random.default_rng(0)\n"   # seeded: allowed
            "    return t, x, rng\n"
        ),
    })
    fs = astlint.run(root, rules={"AST002"})
    assert _ids(fs) == ["AST002"] and {f.line for f in fs} == {4, 5}


def test_ast003_fires_on_unlocked_mailbox(tmp_path):
    root = _mini_repo(tmp_path, {
        "src/repro/launch/badrouter.py": (
            "class R:\n"
            "    def __init__(self, q):\n"
            "        self.commands = q\n"              # __init__: exempt\n
            "    def submit(self, rep, cmd):\n"
            "        rep.commands.put(('submit', cmd))\n"      # fires
            "    def nudge(self, rep):\n"
            "        rep.commands.put(('nudge', None, None))\n"  # exempt
            "    def drain(self, rep):\n"
            "        rep.commands.get_nowait()\n"              # fires
            "    def locked(self, rep, cmd):\n"
            "        with self._lock:\n"
            "            rep.commands.put(('submit', cmd))\n"  # exempt
        ),
    })
    fs = astlint.run(root, rules={"AST003"})
    assert _ids(fs) == ["AST003"] and {f.line for f in fs} == {5, 9}


def test_ast004_fires_on_incomplete_kernel_package(tmp_path):
    root = _mini_repo(tmp_path, {
        "src/repro/kernels/mykern/kernel.py": "X = 1\n",
        "tests/test_other.py": "import repro\n",
    })
    fs = astlint.run(root, rules={"AST004"})
    msgs = " ".join(f.message for f in fs)
    assert _ids(fs) == ["AST004"] and len(fs) == 3
    assert "ref.py" in msgs and "ops.py" in msgs and "parity test" in msgs


def test_ast005_fires_on_unknown_rule_id(tmp_path):
    root = _mini_repo(tmp_path, {
        "src/repro/models/stale.py": "x = 1  # lint: skip[AST999]\n",
    })
    fs = astlint.run(root, rules={"AST005"})
    assert _ids(fs) == ["AST005"] and "AST999" in fs[0].message


# --------------------------------------------------------------------------
# program rules: violating traces each
# --------------------------------------------------------------------------

def _report(fn, *args, donated=False, name="fixture", compile_=False):
    tr = jax.jit(fn).trace(*args)
    low = tr.lower()
    return programlint.EntryReport(
        name, tr.jaxpr, low.as_text(),
        low.compile().as_text() if compile_ else None, donated)


def test_prg001_fires_on_weight_sized_const():
    big = jnp.zeros((512, 256), jnp.float32)    # 128Ki elems, closed over

    def f(x):
        return x @ big

    fs = programlint._check_dtypes(_report(f, jnp.ones((4, 512), jnp.float32)))
    assert _ids(fs) == ["PRG001"]
    assert any("constant" in f.message for f in fs)


def test_prg001_fires_on_f64():
    with jax.experimental.enable_x64():
        fs = programlint._check_dtypes(
            _report(lambda x: x * 2.0, jnp.ones((4,), jnp.float64)))
    assert _ids(fs) == ["PRG001"]
    assert any("float64" in f.message or "f64" in f.message for f in fs)


def test_prg002_fires_on_callback_in_scan():
    def f(x):
        def body(c, _):
            jax.debug.print("step {c}", c=c.sum())
            return c * 2.0, ()
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    fs = programlint._check_callbacks(_report(f, jnp.ones((4,), jnp.float32)))
    assert _ids(fs) == ["PRG002"]


def test_prg003_fires_on_dropped_donation():
    @functools.partial(jax.jit, donate_argnums=(0,))
    def f(x):
        return jnp.zeros((3, 5), jnp.float32)   # no output aliases x

    tr = f.trace(jnp.ones((4,), jnp.float32))
    rep = programlint.EntryReport("fixture", tr.jaxpr,
                                  tr.lower().as_text(), None, donated=True)
    fs = programlint._check_donation(rep)
    assert _ids(fs) == ["PRG003"]


def test_prg003_clean_on_honored_donation():
    @functools.partial(jax.jit, donate_argnums=(0,))
    def f(x):
        return x + 1.0

    tr = f.trace(jnp.ones((4,), jnp.float32))
    low = tr.lower()
    rep = programlint.EntryReport("fixture", tr.jaxpr, low.as_text(),
                                  low.compile().as_text(), donated=True)
    assert programlint._check_donation(rep) == []


def test_prg004_fires_on_vmem_overflow():
    huge = programlint.TTShape(
        "huge", 512, ((8192, 4096), (4096, 8192, 1)), 1, ("f32", "f32"))
    fs = programlint.check_vmem_shapes([huge])
    assert _ids(fs) == ["PRG004"]
    assert "unfused fallback" in fs[0].message


def test_prg004_registered_shapes_fit():
    assert programlint.check_vmem_shapes() == []


# --------------------------------------------------------------------------
# the real tree is clean
# --------------------------------------------------------------------------

def test_repo_ast_layer_clean():
    fs = astlint.run(REPO_ROOT)
    assert fs == [], "\n".join(f.render() for f in fs)


def test_repo_program_entry_clean():
    # one cheap real entry end to end; the CI analysis lane sweeps them all
    fs = programlint.run(fast=True, entries=["admission"])
    fs += programlint.check_vmem_shapes()
    assert fs == [], "\n".join(f.render() for f in fs)


@pytest.mark.slow
def test_repo_program_layer_clean_fast_sweep():
    fs = programlint.run(fast=True)
    assert fs == [], "\n".join(f.render() for f in fs)


def test_check_cli_ast_layer(capsys):
    rc = check_main(["--strict", "--layer", "ast", "--root", str(REPO_ROOT)])
    out = capsys.readouterr().out
    assert rc == 0 and "clean" in out


def test_check_cli_list_rules(capsys):
    rc = check_main(["--list-rules"])
    out = capsys.readouterr().out
    assert rc == 0
    for rid in ("AST001", "AST004", "PRG001", "PRG004"):
        assert rid in out


def test_run_checks_rule_filter():
    fs = run_checks(layer="ast", rules=["AST004"], root=str(REPO_ROOT))
    assert fs == []
