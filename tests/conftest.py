import os
import sys

# Tests run on the single real CPU device — the 512-device dry-run flag is
# set ONLY inside repro/launch/dryrun.py (subprocess tests spawn it fresh).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
