"""TT-native serving coverage: every family in the zoo serves from cores.

One reduced config per architecture family (transformer/dense, encdec,
ssm, hybrid, moe) goes through the full pipeline — spectral-decayed init →
TTCompressor payload → ``tt_native_params(family=...)`` → decode + prefill
— and must match reconstruct-then-serve inside the shared ``logit_parity``
bound while shrinking resident weight bytes.  This is the test-side twin of
the ``benchmarks/tt_serve.run_families`` CI lane.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompressionPolicy,
    TTCompressor,
    is_tt_linear,
    spectral_decay_pytree,
    tt_param_bytes,
)
from repro.models import common as model_common


FAMILY_CASES = [
    # (arch, family, leaves that must serve TT-native)
    ("seamless-m4t-large-v2", "encdec", 18),   # enc+dec attn/cross/mlp
    ("mamba2-1.3b", "ssm", 2),                 # w_in + w_out
    ("recurrentgemma-2b", "hybrid", 21),       # rglru gates + attn + mlps
    ("olmoe-1b-7b", "moe", 7),                 # attn + 3 expert banks
]


def _setup(arch, family):
    from repro.configs import get_config
    from repro.models.registry import build

    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=0.2, min_size=8192))
    payload, _ = comp.compress(params)
    params_rx = comp.decompress(payload)
    params_tt = model_common.tt_native_params(payload, family=family)
    return cfg, model, params_rx, params_tt


def _fill_batch(rng, model, cfg, b, plen):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, plen), np.int32))}
    spec = model.prefill_batch_spec(
        b, plen + (cfg.frontend_len if cfg.frontend else 0))
    for k, s in spec.items():
        if k != "tokens":
            batch[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch,family,n_tt", FAMILY_CASES)
def test_family_serves_tt_native(arch, family, n_tt):
    cfg, model, params_rx, params_tt = _setup(arch, family)

    tt_leaves = [
        leaf for leaf in jax.tree.leaves(params_tt, is_leaf=is_tt_linear)
        if is_tt_linear(leaf)
    ]
    assert len(tt_leaves) == n_tt, [type(x) for x in tt_leaves]
    if family == "moe":
        banks = [l for l in tt_leaves if l.experts]
        assert len(banks) == 3                      # w_gate / w_up / w_down
        assert all(l.experts == cfg.moe.num_experts for l in banks)
    assert tt_param_bytes(params_tt) < tt_param_bytes(params_rx)

    rng = np.random.default_rng(0)
    b, plen = 2, 5
    prompts = rng.integers(0, cfg.vocab_size, (b, plen), np.int32)
    decode = jax.jit(model.decode_step)
    c1 = model.init_cache(b, plen)
    c2 = model.init_cache(b, plen)
    for i in range(plen):
        tok = jnp.asarray(prompts[:, i:i + 1])
        l1, c1 = decode(params_rx, c1, tok)
        l2, c2 = decode(params_tt, c2, tok)
    d, scale, agree = model_common.logit_parity(l2, l1)
    assert d <= max(0.05 * scale, 1e-3), (arch, d, scale)
    assert agree == 1.0

    # prefill/forward takes the TT-aware scans too (encode for encdec,
    # triple+tail for hybrid, SSD chunked path for ssm, MoE dispatch)
    batch = _fill_batch(rng, model, cfg, b, plen)
    p1 = model.prefill(params_rx, batch)
    p2 = model.prefill(params_tt, batch)
    dp, pscale, _ = model_common.logit_parity(p2, p1)
    assert dp <= max(0.05 * pscale, 1e-3), (arch, dp, pscale)


@pytest.mark.slow
def test_encdec_memory_cache_from_tt_cores():
    """Cross-attn memory K/V precompute works on TT-native dec layers
    (lax.map over layer indices instead of vmap over stacked arrays)."""
    from repro.models import encdec as encdec_mod

    cfg, model, params_rx, params_tt = _setup(
        "seamless-m4t-large-v2", "encdec")
    rng = np.random.default_rng(1)
    memory = jnp.asarray(
        rng.standard_normal((2, cfg.frontend_len, cfg.d_model)), jnp.bfloat16)
    cache = model.init_cache(2, 8)
    c_tt = encdec_mod.precompute_memory_cache(params_tt, memory, cfg, cache)
    c_rx = encdec_mod.precompute_memory_cache(params_rx, memory, cfg, cache)
    for a, b in ((c_tt.mem_k, c_rx.mem_k), (c_tt.mem_v, c_rx.mem_v)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        scale = max(np.abs(b).max(), 1e-6)
        np.testing.assert_allclose(a, b, atol=0.05 * scale)
