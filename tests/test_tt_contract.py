"""tt_contract kernel-vs-ref equivalence across core depths and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tt_contract.ops import (
    tt_contract, tt_contract_ref, tt_dense_ref,
)


def _mk_chain(rng, mode_dims, ranks):
    """Lead-absorbed chain: cores[0] (n1, r1) 2D, rest (r, n, s), last s=1."""
    cores = [jnp.asarray(
        rng.standard_normal((mode_dims[0], ranks[0])), jnp.float32)]
    rs = list(ranks) + [1]
    for k in range(1, len(mode_dims)):
        cores.append(jnp.asarray(
            rng.standard_normal((rs[k - 1], mode_dims[k], rs[k])),
            jnp.float32,
        ))
    return cores


CASES = [
    # (mode_dims, ranks, split) — depth 2/3 take the fused Pallas kernels,
    # deeper chains the jnp fallback; splits cover (D,F), (D,H,K), (H,K,D)
    ([128, 256], [7], 1),            # mlp-style, 2-core fused
    ([64, 4, 32], [5, 9], 1),        # wq-style, 3-core fused (split 1)
    ([4, 32, 64], [5, 9], 2),        # wo-style, 3-core fused (split 2)
    ([8, 16, 16, 16], [3, 5, 7], 2),     # depth-4 fallback
    ([6, 7, 8, 9, 10], [2, 3, 4, 5], 3),  # depth-5 fallback
]


@pytest.mark.parametrize("mode_dims,ranks,split", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tt_contract_matches_dense(rng, mode_dims, ranks, split, dtype):
    """Fused/fallback contraction == x @ dense-reconstructed matrix."""
    cores = _mk_chain(rng, mode_dims, ranks)
    n_in = int(np.prod(mode_dims[:split]))
    x = jnp.asarray(rng.standard_normal((9, n_in)), dtype)
    w = tt_dense_ref(cores, split)
    y_dense = np.asarray(x, np.float32) @ np.asarray(w)
    y = np.asarray(tt_contract(x, cores, split))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    scale = max(np.abs(y_dense).max(), 1e-6)
    np.testing.assert_allclose(y, y_dense, atol=tol * scale)


@pytest.mark.parametrize("mode_dims,ranks,split", CASES)
def test_tt_contract_kernel_vs_ref(rng, mode_dims, ranks, split):
    """Kernel dispatch output is bitwise-comparable to the einsum chain."""
    cores = _mk_chain(rng, mode_dims, ranks)
    n_in = int(np.prod(mode_dims[:split]))
    x = jnp.asarray(rng.standard_normal((12, n_in)), jnp.float32)
    y_ref = np.asarray(tt_contract_ref(x, cores, split))
    y = np.asarray(tt_contract(x, cores, split))
    scale = max(np.abs(y_ref).max(), 1e-6)
    np.testing.assert_allclose(y, y_ref, atol=1e-5 * scale)


def test_tt_contract_uneven_batch(rng):
    """Token counts that don't tile (prime B) still work — whole-B grid."""
    cores = _mk_chain(rng, [32, 48], [4])
    x = jnp.asarray(rng.standard_normal((13, 32)), jnp.float32)
    y = np.asarray(tt_contract(x, cores, 1))
    w = np.asarray(tt_dense_ref(cores, 1))
    np.testing.assert_allclose(
        y, np.asarray(x) @ w, atol=1e-5 * max(np.abs(w).max(), 1.0)
    )
