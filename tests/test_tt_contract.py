"""tt_contract kernel-vs-ref equivalence across core depths and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize_array
from repro.kernels.tt_contract.ops import (
    tt_contract, tt_contract_batched, tt_contract_batched_ref,
    tt_contract_ref, tt_dense_ref, tt_dequant_chain,
)


def _mk_chain(rng, mode_dims, ranks):
    """Lead-absorbed chain: cores[0] (n1, r1) 2D, rest (r, n, s), last s=1."""
    cores = [jnp.asarray(
        rng.standard_normal((mode_dims[0], ranks[0])), jnp.float32)]
    rs = list(ranks) + [1]
    for k in range(1, len(mode_dims)):
        cores.append(jnp.asarray(
            rng.standard_normal((rs[k - 1], mode_dims[k], rs[k])),
            jnp.float32,
        ))
    return cores


CASES = [
    # (mode_dims, ranks, split) — depth 2/3 take the fused Pallas kernels,
    # deeper chains the jnp fallback; splits cover (D,F), (D,H,K), (H,K,D)
    ([128, 256], [7], 1),            # mlp-style, 2-core fused
    ([64, 4, 32], [5, 9], 1),        # wq-style, 3-core fused (split 1)
    ([4, 32, 64], [5, 9], 2),        # wo-style, 3-core fused (split 2)
    ([8, 16, 16, 16], [3, 5, 7], 2),     # depth-4 fallback
    ([6, 7, 8, 9, 10], [2, 3, 4, 5], 3),  # depth-5 fallback
]


@pytest.mark.parametrize("mode_dims,ranks,split", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tt_contract_matches_dense(rng, mode_dims, ranks, split, dtype):
    """Fused/fallback contraction == x @ dense-reconstructed matrix."""
    cores = _mk_chain(rng, mode_dims, ranks)
    n_in = int(np.prod(mode_dims[:split]))
    x = jnp.asarray(rng.standard_normal((9, n_in)), dtype)
    w = tt_dense_ref(cores, split)
    y_dense = np.asarray(x, np.float32) @ np.asarray(w)
    y = np.asarray(tt_contract(x, cores, split))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    scale = max(np.abs(y_dense).max(), 1e-6)
    np.testing.assert_allclose(y, y_dense, atol=tol * scale)


@pytest.mark.parametrize("mode_dims,ranks,split", CASES)
def test_tt_contract_kernel_vs_ref(rng, mode_dims, ranks, split):
    """Kernel dispatch output is bitwise-comparable to the einsum chain."""
    cores = _mk_chain(rng, mode_dims, ranks)
    n_in = int(np.prod(mode_dims[:split]))
    x = jnp.asarray(rng.standard_normal((12, n_in)), jnp.float32)
    y_ref = np.asarray(tt_contract_ref(x, cores, split))
    y = np.asarray(tt_contract(x, cores, split))
    scale = max(np.abs(y_ref).max(), 1e-6)
    np.testing.assert_allclose(y, y_ref, atol=1e-5 * scale)


def test_tt_contract_uneven_batch(rng):
    """Token counts that don't tile (prime B) still work — whole-B grid."""
    cores = _mk_chain(rng, [32, 48], [4])
    x = jnp.asarray(rng.standard_normal((13, 32)), jnp.float32)
    y = np.asarray(tt_contract(x, cores, 1))
    w = np.asarray(tt_dense_ref(cores, 1))
    np.testing.assert_allclose(
        y, np.asarray(x) @ w, atol=1e-5 * max(np.abs(w).max(), 1.0)
    )


# ---------------------------------------------------------------------------
# Deep-chain ref fallback: the einsum chain itself vs dense materialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode_dims,ranks,split", [
    ([8, 16, 16, 16], [3, 5, 7], 1),
    ([8, 16, 16, 16], [3, 5, 7], 3),
    ([4, 6, 8, 10, 12, 6], [2, 3, 4, 3, 2], 3),   # depth-6
])
def test_tt_contract_ref_deep_matches_dense(rng, mode_dims, ranks, split):
    """Depth >= 4 never fuses — pin the fallback oracle itself against the
    reconstruct-then-matmul baseline across split positions."""
    cores = _mk_chain(rng, mode_dims, ranks)
    n_in = int(np.prod(mode_dims[:split]))
    x = jnp.asarray(rng.standard_normal((7, n_in)), jnp.float32)
    y = np.asarray(tt_contract_ref(x, cores, split))
    w = np.asarray(tt_dense_ref(cores, split))
    y_dense = np.asarray(x) @ w
    np.testing.assert_allclose(
        y, y_dense, atol=1e-5 * max(np.abs(y_dense).max(), 1e-6)
    )


# ---------------------------------------------------------------------------
# Expert-batched chain (MoE banks): vmapped kernel vs extended ref oracle
# ---------------------------------------------------------------------------

BATCHED_CASES = [
    ([64, 96], [5], 1),              # 2-core fused per expert
    ([32, 4, 24], [5, 7], 1),        # 3-core fused, split 1
    ([4, 16, 48], [5, 7], 2),        # 3-core fused, split 2
    ([8, 8, 8, 8], [3, 4, 5], 2),    # depth-4 per-expert fallback
]


@pytest.mark.parametrize("mode_dims,ranks,split", BATCHED_CASES)
def test_tt_contract_batched_matches_ref_and_dense(rng, mode_dims, ranks,
                                                   split):
    """Expert-batched dispatch == extended einsum oracle == per-expert
    dense matmuls (experts share tail cores, differ in the lead-absorbed
    first core — exactly what an expert-axis TTLinear hands down)."""
    e, b = 5, 6
    g0b = jnp.asarray(
        rng.standard_normal((e, mode_dims[0], ranks[0])), jnp.float32)
    rest = _mk_chain(rng, mode_dims, ranks)[1:]
    n_in = int(np.prod(mode_dims[:split]))
    x3 = jnp.asarray(rng.standard_normal((e, b, n_in)), jnp.float32)

    y = np.asarray(tt_contract_batched(x3, g0b, rest, split))
    y_ref = np.asarray(tt_contract_batched_ref(x3, g0b, rest, split))
    scale = max(np.abs(y_ref).max(), 1e-6)
    np.testing.assert_allclose(y, y_ref, atol=1e-5 * scale)
    for ei in range(e):
        w = np.asarray(tt_dense_ref([g0b[ei]] + rest, split))
        np.testing.assert_allclose(
            y[ei], np.asarray(x3[ei]) @ w, atol=1e-5 * scale
        )


# ---------------------------------------------------------------------------
# Quantized chains: int8 tail cores, dequantization fused into the kernels
# ---------------------------------------------------------------------------

def _quantize_tail(cores):
    """TTLinear-style quantized chain: wide lead-absorbed first core (its
    scale folded host-side), int8 tail cores + per-core scales."""
    qcores, scales = [cores[0]], [None]
    for g in cores[1:]:
        q, s = quantize_array(g)
        qcores.append(q)
        scales.append(s)
    return qcores, scales


@pytest.mark.parametrize("mode_dims,ranks,split", CASES)
def test_tt_contract_quantized_matches_dequant_ref(rng, mode_dims, ranks,
                                                   split):
    """Fused-dequant dispatch (scale folded into the output tile) == the
    explicit dequantize-then-einsum oracle at f32 tolerance, across the
    fused depths AND the deep-chain fallback (which applies the scale
    product outside the ref chain)."""
    cores = _mk_chain(rng, mode_dims, ranks)
    qcores, scales = _quantize_tail(cores)
    n_in = int(np.prod(mode_dims[:split]))
    x = jnp.asarray(rng.standard_normal((12, n_in)), jnp.float32)
    y = np.asarray(tt_contract(x, qcores, split, scales=scales))
    y_ref = np.asarray(
        tt_contract_ref(x, tt_dequant_chain(qcores, scales), split)
    )
    scale = max(np.abs(y_ref).max(), 1e-6)
    np.testing.assert_allclose(y, y_ref, atol=1e-5 * scale)
    # and the dequantized chain stays close to the unquantized one: int8
    # symmetric rounding moves each core <= scale/2 per element
    y_exact = np.asarray(tt_contract_ref(x, cores, split))
    assert np.abs(y - y_exact).max() <= 0.05 * max(np.abs(y_exact).max(), 1.0)


@pytest.mark.parametrize("mode_dims,ranks,split", BATCHED_CASES)
def test_tt_contract_batched_quantized_vs_per_expert(rng, mode_dims, ranks,
                                                     split):
    """Quantized expert-batched chain == per-expert dequantize-then-contract
    loop: experts share the int8 tail cores and their scales, so the scale
    product is expert-invariant."""
    e, b = 4, 6
    g0b = jnp.asarray(
        rng.standard_normal((e, mode_dims[0], ranks[0])), jnp.float32)
    rest = _mk_chain(rng, mode_dims, ranks)[1:]
    qrest, tail_scales = [], []
    for g in rest:
        q, s = quantize_array(g)
        qrest.append(q)
        tail_scales.append(s)
    n_in = int(np.prod(mode_dims[:split]))
    x3 = jnp.asarray(rng.standard_normal((e, b, n_in)), jnp.float32)

    y = np.asarray(
        tt_contract_batched(x3, g0b, qrest, split, scales=tail_scales)
    )
    for ei in range(e):
        chain = tt_dequant_chain([g0b[ei]] + qrest, [None] + tail_scales)
        y_ref = np.asarray(tt_contract_ref(x3[ei], chain, split))
        np.testing.assert_allclose(
            y[ei], y_ref, atol=1e-5 * max(np.abs(y_ref).max(), 1e-6)
        )


def test_fits_vmem_accounts_core_itemsize(rng, monkeypatch):
    """Regression: the VMEM gate assumed 4 bytes per core element.  An int8
    chain near the budget occupies a quarter of that — the old accounting
    would bounce it off the fused path it actually fits on.  Craft a budget
    between the int8 and the (hypothetical) uniform-f32 footprint: the
    quantized chain must pass the gate and dispatch fused, the wide chain
    must fail it."""
    from repro.kernels import common as kcommon
    from repro.kernels.tt_contract import kernel as kernel_mod
    from repro.kernels.tt_contract import ops

    cores = _mk_chain(rng, [64, 128], [16])        # tail core 16*128 elements
    qcores, scales = _quantize_tail(cores)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    bb = kernel_mod._grid_1d(32)
    n_out = 128
    acts = 4 * (bb * (64 + n_out) + bb * 16)       # tiles + (bb, r1) interm
    wide_cores = 4 * sum(int(g.size) for g in cores)
    int8_cores = sum(
        int(g.size) * (1 if g.dtype == jnp.int8 else 4) for g in qcores
    )
    # budget straddles the two accountings of the SAME chain
    budget = 2 * (acts + (int8_cores + wide_cores) // 2)
    assert acts + int8_cores < budget // 2 < acts + wide_cores
    monkeypatch.setattr(kcommon, "VMEM_BUDGET", budget)

    assert ops._fits_vmem(x, qcores, n_out, split=1)
    assert not ops._fits_vmem(x, cores, n_out, split=1)

    used = {}
    real = kernel_mod.tt_contract_2q

    def spy(*args, **kw):
        used["fused"] = True
        return real(*args, **kw)

    monkeypatch.setattr(kernel_mod, "tt_contract_2q", spy)
    y = np.asarray(ops.tt_contract(x, qcores, 1, scales=scales))
    assert used.get("fused"), "int8 chain fell off the fused path"
    y_ref = np.asarray(
        tt_contract_ref(x, tt_dequant_chain(qcores, scales), 1)
    )
    np.testing.assert_allclose(
        y, y_ref, atol=1e-5 * max(np.abs(y_ref).max(), 1e-6)
    )


# ---------------------------------------------------------------------------
# VMEM dispatch gate: the depth-3 intermediate tile must be accounted
# ---------------------------------------------------------------------------

def test_fits_vmem_counts_depth3_intermediate(rng, monkeypatch):
    """Regression: a chain whose (bb, n_mid*r2) intermediate pushes the
    fused tile just past the budget must fall back to tt_contract_ref —
    the old accounting (acts + cores only) would have fused it."""
    from repro.kernels import common as kcommon
    from repro.kernels.tt_contract import kernel as kernel_mod
    from repro.kernels.tt_contract import ops

    cores = _mk_chain(rng, [8, 16, 4], [4, 8])     # n_mid*r2 = 128
    x = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    bb = kernel_mod._grid_1d(32)
    n_out = 16 * 4
    acts_and_cores = 4 * (bb * (8 + n_out) + sum(int(g.size) for g in cores))
    interm = 4 * bb * 16 * 8                       # the unaccounted tile
    # budget between the old and the corrected footprint: old accounting
    # says "fits", corrected says "doesn't"
    budget = 2 * (acts_and_cores + interm // 2)
    assert acts_and_cores < budget // 2 < acts_and_cores + interm
    monkeypatch.setattr(kcommon, "VMEM_BUDGET", budget)

    assert not ops._fits_vmem(x, cores, n_out, split=1)

    def boom(*a, **k):                             # fused path must not run
        raise AssertionError("dispatched past the corrected VMEM budget")
    monkeypatch.setattr(kernel_mod, "tt_contract_3", boom)
    y = np.asarray(ops.tt_contract(x, cores, split=1))
    w = np.asarray(tt_dense_ref(cores, 1))
    y_dense = np.asarray(x) @ w
    np.testing.assert_allclose(
        y, y_dense, atol=1e-5 * max(np.abs(y_dense).max(), 1e-6)
    )

    # control: with the intermediate inside the budget the fused path runs
    monkeypatch.setattr(
        kcommon, "VMEM_BUDGET", 4 * (acts_and_cores + 2 * interm)
    )
    assert ops._fits_vmem(x, cores, n_out, split=1)


# ---------------------------------------------------------------------------
# Tunable token-dim tile cap (env var / argument; adaptive default)
# ---------------------------------------------------------------------------

def test_resolve_tile_cap(monkeypatch):
    from repro.kernels.tt_contract import kernel as kernel_mod
    from repro.kernels.tt_contract import ops

    default = kernel_mod.DEFAULT_TILE_CAP
    monkeypatch.delenv("TT_CONTRACT_TILE", raising=False)
    # adaptive default: grows when the token extent divides cleanly, but
    # always keeps the historical cap as a VMEM-gate fallback so a bigger
    # default can only ADD fused coverage
    assert ops.resolve_tile_cap(2048) == (2048, 1024, default)
    assert ops.resolve_tile_cap(3 * 1024) == (1024, default)
    assert ops.resolve_tile_cap(384) == (default,)
    assert ops.resolve_tile_cap(100) == (default,)
    # explicit argument beats everything and is never second-guessed
    assert ops.resolve_tile_cap(2048, tile=64) == (64,)
    # env var beats the adaptive default
    monkeypatch.setenv("TT_CONTRACT_TILE", "256")
    assert ops.resolve_tile_cap(2048) == (256,)
    assert ops.resolve_tile_cap(2048, tile=128) == (128,)


def test_tile_cap_rejects_junk_with_clear_message(monkeypatch):
    """Regression: a non-integer or <= 0 TT_CONTRACT_TILE used to crash
    with an opaque int() ValueError deep in dispatch — the error must name
    the env var (or the tile= argument) so the operator knows what to fix."""
    from repro.kernels.tt_contract import ops

    for junk in ("banana", "1.5", " ", "0", "-128"):
        monkeypatch.setenv("TT_CONTRACT_TILE", junk)
        with pytest.raises(ValueError, match="TT_CONTRACT_TILE"):
            ops.resolve_tile_cap(1024)
    # empty string is falsy → the adaptive default, not an error
    monkeypatch.setenv("TT_CONTRACT_TILE", "")
    assert ops.resolve_tile_cap(100)
    monkeypatch.delenv("TT_CONTRACT_TILE", raising=False)
    # the explicit argument gets the same validation, naming tile= instead
    for junk in (0, -64, "pear"):
        with pytest.raises(ValueError, match="tile="):
            ops.resolve_tile_cap(1024, tile=junk)


def test_tile_cap_changes_grid_not_result(rng):
    """Different tile caps pick different grids but identical outputs, and
    _grid_1d honors the cap it is given."""
    from repro.kernels.tt_contract import kernel as kernel_mod

    assert kernel_mod._grid_1d(2048, 1024) == 1024
    assert kernel_mod._grid_1d(1024, 256) == 256
    assert kernel_mod._grid_1d(96, 512) == 96          # whole-batch block

    cores = _mk_chain(rng, [32, 48], [4])
    x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    y_default = np.asarray(tt_contract(x, cores, 1))
    y_small = np.asarray(tt_contract(x, cores, 1, tile=16))
    y_large = np.asarray(tt_contract(x, cores, 1, tile=4096))
    np.testing.assert_allclose(y_small, y_default, atol=1e-6)
    np.testing.assert_allclose(y_large, y_default, atol=1e-6)


def test_bigger_default_cap_never_evicts_fused(rng, monkeypatch):
    """Regression: a shape whose big-tile footprint flunks the VMEM gate
    must retry at the smaller fallback cap and stay fused, not fall back
    to the unfused chain."""
    from repro.kernels import common as kcommon
    from repro.kernels.tt_contract import kernel as kernel_mod
    from repro.kernels.tt_contract import ops

    monkeypatch.delenv("TT_CONTRACT_TILE", raising=False)
    cores = _mk_chain(rng, [64, 128], [4])
    x = jnp.asarray(rng.standard_normal((2048, 64)), jnp.float32)
    n_out = 128
    # budget between the bb=1024 footprint and the bb=512 one
    assert ops._fits_vmem(x, cores, n_out, 1, 512)
    hi = 4 * (1024 * (64 + 128 + 4) + sum(int(g.size) for g in cores))
    lo = 4 * (512 * (64 + 128 + 4) + sum(int(g.size) for g in cores))
    monkeypatch.setattr(kcommon, "VMEM_BUDGET", (hi + lo))  # lo < B/2 < hi
    assert not ops._fits_vmem(x, cores, n_out, 1, 2048)
    assert not ops._fits_vmem(x, cores, n_out, 1, 1024)
    assert ops._fits_vmem(x, cores, n_out, 1, 512)

    used = {}
    real = kernel_mod.tt_contract_2

    def spy(x2, g0, g1, interpret=False, tile_cap=None):
        used["tile_cap"] = tile_cap
        return real(x2, g0, g1, interpret=interpret, tile_cap=tile_cap)

    monkeypatch.setattr(kernel_mod, "tt_contract_2", spy)
    y = np.asarray(ops.tt_contract(x, cores, 1))
    assert used["tile_cap"] == 512            # retried down, stayed fused
    w = np.asarray(tt_dense_ref(cores, 1))
    np.testing.assert_allclose(
        y, np.asarray(x) @ w, atol=1e-5 * max(np.abs(w).max(), 1.0)
    )
