"""Per-kernel oracle sweeps: shapes × dtypes, interpret mode vs ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_update.ops import block_wy_update, wy_update_ref
from repro.kernels.flash_attention.ops import mha_flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.frob_truncate.ops import (
    delta_truncate, delta_truncate_batched, frob_truncate_ref,
)
from repro.kernels.householder.ops import (
    build_t, panel_factor, panel_factor_batched, panel_factor_ref,
    qr_blocked,
)
from repro.kernels.singular_sort.ops import (
    sort_singular_values, sort_singular_values_batched, sorting_basis,
    sort_desc_ref,
)


# ---------------------------------------------------------------------------
# block_update (WY trailing update)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,b", [
    (256, 256, 32), (300, 200, 16), (128, 512, 64), (64, 64, 8),
    (260, 130, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wy_update_sweep(rng, m, n, b, dtype):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype)
    v = jnp.asarray(rng.standard_normal((m, b)), dtype)
    t = jnp.asarray(np.triu(rng.standard_normal((b, b))) * 0.1, dtype)
    out = block_wy_update(a, v, t)
    ref = wy_update_ref(a, v, t)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    scale = max(float(jnp.abs(ref).max()), 1.0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol * scale,
    )


# ---------------------------------------------------------------------------
# householder panel (HBD-ACC)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,b", [(64, 16), (128, 32), (96, 8), (32, 32)])
def test_panel_factor_sweep(rng, m, b):
    a = jnp.asarray(rng.standard_normal((m, b)).astype(np.float32))
    v, tau, r = panel_factor(a)
    vr, taur, rr = panel_factor_ref(a)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(tau), np.asarray(taur), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(rr), atol=2e-5)


@pytest.mark.parametrize("m,n,p", [(96, 64, 16), (200, 100, 32), (64, 64, 64)])
def test_qr_blocked(rng, m, n, p):
    a = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    q, r = qr_blocked(a, panel=p)
    np.testing.assert_allclose(
        np.asarray(q) @ np.asarray(r), np.asarray(a),
        atol=1e-4 * np.sqrt(m * n),
    )
    np.testing.assert_allclose(
        np.asarray(q).T @ np.asarray(q), np.eye(n), atol=5e-5
    )
    assert np.abs(np.tril(np.asarray(r), -1)).max() == 0


@pytest.mark.parametrize("bsz,m,b", [(1, 64, 16), (5, 48, 16), (8, 96, 8)])
def test_panel_factor_batched_matches_serial(rng, bsz, m, b):
    """Batch grid dimension: member k of one launch == serial call k."""
    a = jnp.asarray(rng.standard_normal((bsz, m, b)).astype(np.float32))
    vb, tb, rb = panel_factor_batched(a)
    assert vb.shape == (bsz, m, b) and tb.shape == (bsz, b)
    for k in range(bsz):
        v, t, r = panel_factor(a[k])
        np.testing.assert_allclose(np.asarray(vb[k]), np.asarray(v),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(tb[k]), np.asarray(t),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(rb[k]), np.asarray(r),
                                   atol=1e-6)


def test_wy_identity_vs_explicit_product(rng):
    """I - V T V^T must equal the product of the panel's reflectors."""
    m, b = 40, 8
    a = jnp.asarray(rng.standard_normal((m, b)).astype(np.float32))
    v, tau, _ = panel_factor(a)
    t = build_t(v, tau)
    wy = np.eye(m) - np.asarray(v) @ np.asarray(t) @ np.asarray(v).T
    prod = np.eye(m)
    for j in range(b):
        vv = np.asarray(v)[:, j]
        prod = prod @ (np.eye(m) - float(tau[j]) * np.outer(vv, vv))
    np.testing.assert_allclose(wy, prod, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,d,causal,win", [
    (2, 256, 4, 2, 64, True, None),
    (1, 128, 8, 8, 32, False, None),
    (2, 256, 4, 1, 64, True, 64),
    (1, 512, 2, 1, 128, True, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, b, s, hq, hkv, d, causal, win, dtype):
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    out = mha_flash(q, k, v, causal=causal, window=win)
    rep = hq // hkv
    kr = jnp.repeat(k, rep, 2).transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    vr = jnp.repeat(v, rep, 2).transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    ref = attention_ref(qr, kr, vr, causal=causal, window=win)
    ref = ref.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


# ---------------------------------------------------------------------------
# singular sort (SORTING module)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 7, 16, 100, 255, 512])
def test_bitonic_sort_sweep(rng, n):
    s = jnp.asarray(np.abs(rng.standard_normal(n)).astype(np.float32))
    ss, idx = sort_singular_values(s)
    sr, ir = sort_desc_ref(s)
    np.testing.assert_array_equal(np.asarray(ss), np.asarray(sr))
    # index vector validity: s[idx] == sorted
    np.testing.assert_array_equal(np.asarray(s)[np.asarray(idx)],
                                  np.asarray(ss))
    assert sorted(np.asarray(idx).tolist()) == list(range(n))


@pytest.mark.parametrize("bsz,n", [(1, 16), (4, 33), (6, 100)])
def test_bitonic_sort_batched_matches_serial(rng, bsz, n):
    s = jnp.asarray(np.abs(rng.standard_normal((bsz, n))).astype(np.float32))
    sb, ib = sort_singular_values_batched(s)
    for k in range(bsz):
        ss, ii = sort_singular_values(s[k])
        np.testing.assert_array_equal(np.asarray(sb[k]), np.asarray(ss))
        np.testing.assert_array_equal(np.asarray(ib[k]), np.asarray(ii))


def test_sorting_basis_contract(rng):
    """Kernel sorting_basis must preserve U Σ V^T (paper Alg. 1 l.18-25)."""
    m, k, n = 10, 6, 8
    u = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    s = jnp.asarray(np.abs(rng.standard_normal(k)).astype(np.float32))
    vt = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    us, ss, vts = sorting_basis(u, s, vt)
    before = np.asarray(u) @ np.diag(np.asarray(s)) @ np.asarray(vt)
    after = np.asarray(us) @ np.diag(np.asarray(ss)) @ np.asarray(vts)
    np.testing.assert_allclose(after, before, atol=1e-5)


# ---------------------------------------------------------------------------
# frob truncate (TRUNCATION module)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 5, 50, 200])
@pytest.mark.parametrize("delta", [1e-3, 0.5, 2.0, 1e3])
def test_frob_truncate_sweep(rng, n, delta):
    s = jnp.asarray(
        np.sort(np.abs(rng.standard_normal(n)).astype(np.float32))[::-1].copy()
    )
    tail, rank = delta_truncate(s, delta)
    tail_r, rank_r = frob_truncate_ref(s, delta)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(tail_r),
                               rtol=1e-6)
    assert int(rank) == int(rank_r)


@pytest.mark.parametrize("bsz,n", [(1, 8), (3, 20), (5, 64)])
def test_frob_truncate_batched_matches_serial(rng, bsz, n):
    """Per-member δ budgets applied by one batch-grid launch."""
    s = jnp.asarray(
        np.sort(np.abs(rng.standard_normal((bsz, n))).astype(np.float32),
                axis=1)[:, ::-1].copy()
    )
    deltas = jnp.asarray(
        np.abs(rng.standard_normal(bsz)).astype(np.float32) + 0.1
    )
    tb, rb = delta_truncate_batched(s, deltas)
    for k in range(bsz):
        t, r = delta_truncate(s[k], deltas[k])
        np.testing.assert_allclose(np.asarray(tb[k]), np.asarray(t),
                                   rtol=1e-6)
        assert int(rb[k]) == int(r)
