"""Property-based tests (hypothesis) for the TT decomposition invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis ships in the [test] extra; skip (never break collection) when
# running against a bare runtime install
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as core
from repro.core import truncation as trunc

_dims = st.lists(st.integers(2, 6), min_size=2, max_size=4)


@settings(max_examples=25, deadline=None)
@given(dims=_dims, eps=st.sampled_from([0.01, 0.05, 0.2, 0.5]),
       seed=st.integers(0, 2**16))
def test_tt_error_bound(dims, eps, seed):
    """The paper's δ = ε/√(d-1)·||W||_F budget guarantees the global bound
    ||W - W_R||_F <= ε ||W||_F (Oseledets 2011, Thm 2.2)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(dims).astype(np.float32)
    tt = core.ttd(w, eps=eps, svd_method="library")
    rec = np.asarray(core.tt_reconstruct(tt))
    rel = np.linalg.norm(rec - w) / max(np.linalg.norm(w), 1e-30)
    assert rel <= eps + 1e-5


@settings(max_examples=15, deadline=None)
@given(dims=_dims, seed=st.integers(0, 2**16))
def test_tt_rank_bounds(dims, seed):
    """TT ranks never exceed min(prod-left, prod-right)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(dims).astype(np.float32)
    tt = core.ttd(w, eps=0.1, svd_method="library")
    rmax = core.tt_max_ranks(dims, max_rank=10**9)
    for k, r in enumerate(tt.ranks):
        assert r <= rmax[k]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16),
       eps=st.sampled_from([0.05, 0.3]))
def test_static_matches_dynamic(seed, eps):
    """Padded/masked in-graph TT must reconstruct EXACTLY like the dynamic
    path (the invariant comm_compress relies on)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((4, 5, 6)).astype(np.float32)
    dyn = core.ttd(w, eps=eps, svd_method="library")
    stat = core.ttd_static(jnp.asarray(w), eps=eps, max_rank=64)
    rec_d = np.asarray(core.tt_reconstruct(dyn))
    rec_s = np.asarray(core.static_tt_reconstruct(stat))
    np.testing.assert_allclose(rec_s, rec_d, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(stat.ranks), np.asarray(list(dyn.ranks))
    )


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 2**16),
       delta=st.floats(1e-3, 100.0))
def test_truncation_rank_monotone(n, seed, delta):
    """δ-truncation: kept rank is in [1, n]; discarded tail < δ; kept head
    (if any discard happened) has tail >= δ at the cut."""
    rng = np.random.default_rng(seed)
    s = np.sort(np.abs(rng.standard_normal(n)).astype(np.float32))[::-1]
    r = trunc.truncation_rank(s, delta)
    assert 1 <= r <= n
    if r < n:
        assert np.linalg.norm(s[r:]) < delta
    static_r = int(trunc.truncation_rank_static(jnp.asarray(s),
                                                jnp.asarray(delta)))
    assert static_r == r


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_low_rank_compresses(seed):
    """A (noisy) low-rank tensor must compress by > 1.5x at matched eps."""
    rng = np.random.default_rng(seed)
    u = rng.standard_normal((64, 4)).astype(np.float32)
    v = rng.standard_normal((4, 60)).astype(np.float32)
    w = (u @ v).reshape(8, 8, 6, 10)
    w += 0.001 * rng.standard_normal(w.shape).astype(np.float32)
    tt = core.ttd(w, eps=0.05, svd_method="library")
    assert tt.compression_ratio > 1.5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_tensorize_preserves_numel(seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(2, 300, size=2))
    dims = core.tensorize_shape(shape, max_factor=32)
    assert int(np.prod(dims)) == int(np.prod(shape))
    assert all(d <= max(32, max(shape)) for d in dims)


def test_two_phase_inside_ttd(rng):
    """Algorithm 1 with the paper's own two-phase SVD as the inner kernel."""
    w = rng.standard_normal((6, 7, 8)).astype(np.float32)
    tt = core.ttd(w, eps=0.1, svd_method="two_phase")
    rec = np.asarray(core.tt_reconstruct(tt))
    rel = np.linalg.norm(rec - w) / np.linalg.norm(w)
    assert rel <= 0.1 + 1e-5
