"""Batched compression planner/executor: determinism, serial equivalence,
padded-bucket ε bound, round-robin scheduling."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core.batch_exec import round_robin_chunks
from repro.core.compression import CompressionPolicy, TTCompressor
from repro.core import tt as _tt


def _lowrank(rng, shape, r=4):
    m = int(shape[0])
    n = int(np.prod(shape[1:]))
    w = (rng.standard_normal((m, r)) @ rng.standard_normal((r, n)))
    return jnp.asarray(w.reshape(shape).astype(np.float32))


def _mixed_pytree(rng):
    """Conv kernels (two shared shapes + one pad-compatible), a matrix that
    gets re-tensorized, and raw passthroughs."""
    return {
        "s0": {"conv1": _lowrank(rng, (16, 12, 3, 3)),
               "conv2": _lowrank(rng, (16, 12, 3, 3)),
               "bn": jnp.ones((16,), jnp.float32)},
        "s1": {"conv1": _lowrank(rng, (16, 10, 3, 3))},   # pads into 16x12
        "fc": _lowrank(rng, (64, 48)),
        "bias": jnp.zeros((10,), jnp.float32),
    }


POLICY = CompressionPolicy(eps=0.08, min_size=256, svd_method="library")


def test_plan_deterministic(rng):
    params = _mixed_pytree(rng)
    p1 = plan_mod.build_plan(params, POLICY)
    p2 = plan_mod.build_plan(params, POLICY)
    assert p1.fingerprint == p2.fingerprint
    assert p1 == p2                        # frozen dataclasses: full equality
    # every leaf is routed exactly once
    routed = [m.index for b in p1.buckets for m in b.members]
    routed += [e.index for e in p1.raw]
    assert sorted(routed) == list(range(p1.num_leaves))


def test_plan_buckets_same_shapes_together(rng):
    params = _mixed_pytree(rng)
    p = plan_mod.build_plan(params, POLICY)
    by_dims = {b.dims: b for b in p.buckets}
    # the two (16,12,3,3) convs and the pad-compatible (16,10,3,3) share one
    # bucket (pad overhead 12/10 - 1 = 0.2 <= 0.25)
    assert (16, 12, 3, 3) in by_dims
    assert by_dims[(16, 12, 3, 3)].batch == 3
    padded = [m for m in by_dims[(16, 12, 3, 3)].members
              if m.dims != (16, 12, 3, 3)]
    assert len(padded) == 1 and padded[0].dims == (16, 10, 3, 3)


def test_plan_pad_tolerance_zero_disables_merge(rng):
    params = _mixed_pytree(rng)
    p = plan_mod.build_plan(params, POLICY, pad_tolerance=0.0)
    dims = {b.dims for b in p.buckets}
    assert (16, 10, 3, 3) in dims          # kept as its own bucket


def test_batched_matches_serial_oracle(rng):
    params = _mixed_pytree(rng)
    comp = TTCompressor(POLICY)
    cb, rb = comp.compress(params, plan="batched")
    cs, rs = comp.compress(params, plan="serial")
    # identical routing decisions and payload accounting
    assert {k: v[0] for k, v in rb.per_param.items()} == \
        {k: v[0] for k, v in rs.per_param.items()}
    bb = comp.decompress(cb)
    ss = comp.decompress(cs)
    import jax
    for (pb, ps) in zip(jax.tree.leaves(bb), jax.tree.leaves(ss)):
        # same-shape bucket members are bit-exact vs serial; the padded
        # member only differs by float association in the padded SVD
        np.testing.assert_allclose(
            np.asarray(pb), np.asarray(ps), atol=1e-4
        )


def test_padded_member_keeps_eps_bound(rng):
    """Zero-padding into a bigger bucket must not break ‖W-R‖ <= ε‖W‖."""
    eps = 0.1
    pol = CompressionPolicy(eps=eps, min_size=64, svd_method="library",
                            pad_tolerance=0.5)
    w = _lowrank(rng, (8, 5, 3, 3), r=3)
    w = w + 0.01 * jnp.asarray(
        rng.standard_normal(w.shape).astype(np.float32))
    params = {"big": _lowrank(rng, (8, 6, 3, 3)), "padded": w}
    comp = TTCompressor(pol)
    compressed, report = comp.compress(params, plan="batched")
    plan = plan_mod.build_plan(params, pol, pad_tolerance=0.5)
    assert len(plan.buckets) == 1 and plan.buckets[0].batch == 2
    back = comp.decompress(compressed)
    rel = float(jnp.linalg.norm(back["padded"] - w) / jnp.linalg.norm(w))
    assert rel <= eps + 1e-5
    assert back["padded"].shape == w.shape


def test_raw_passthrough_bitexact(rng):
    params = _mixed_pytree(rng)
    comp = TTCompressor(POLICY)
    cb, _ = comp.compress(params, plan="batched")
    back = comp.decompress(cb)
    np.testing.assert_array_equal(np.asarray(back["s0"]["bn"]),
                                  np.asarray(params["s0"]["bn"]))
    np.testing.assert_array_equal(np.asarray(back["bias"]),
                                  np.asarray(params["bias"]))


def test_dispatch_reduction_reported(rng):
    params = _mixed_pytree(rng)
    comp = TTCompressor(POLICY)
    _, report = comp.compress(params, plan="batched")
    st = report.exec_stats
    assert st is not None
    assert st.bucket_launches == len(
        plan_mod.build_plan(params, POLICY).buckets)
    assert st.serial_equiv_dispatches > st.total_dispatches
    assert report.plan_fingerprint


def test_serial_cutoff_falls_back(rng):
    """Buckets beyond the padded-work bound must run the serial path."""
    params = {"w": _lowrank(rng, (16, 12, 3, 3))}
    pol = CompressionPolicy(eps=0.1, min_size=64, svd_method="library",
                            serial_cutoff_elems=10)   # absurdly low bound
    p = plan_mod.build_plan(params, pol, serial_cutoff_elems=10)
    assert all(b.execution == "serial" for b in p.buckets)
    comp = TTCompressor(pol)
    cb, rb = comp.compress(params, plan="batched")
    assert rb.exec_stats.bucket_launches == 0
    assert rb.exec_stats.serial_params == 1
    back = comp.decompress(cb)
    np.testing.assert_allclose(
        np.asarray(back["w"]), np.asarray(params["w"]), atol=0.1 * 100
    )


def test_round_robin_chunks():
    chunks = round_robin_chunks(7, 3)
    assert len(chunks) == 3
    assert chunks[0] == [0, 3, 6]
    assert chunks[1] == [1, 4, -1]         # padded to equal length
    assert chunks[2] == [2, 5, -1]
    # degenerate cases
    assert round_robin_chunks(2, 1) == [[0, 1]]
    assert round_robin_chunks(0, 2) == [[], []]


def test_ttd_static_batched_matches_serial():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((4, 6, 5, 4)).astype(np.float32))
    batched = _tt.ttd_static_batched(w, eps=0.1, max_rank=32,
                                     svd_method="library")
    for k in range(4):
        member = _tt.static_tt_member(batched, k)
        serial = _tt.ttd_static(w[k], eps=0.1, max_rank=32,
                                svd_method="library")
        np.testing.assert_array_equal(np.asarray(member.ranks),
                                      np.asarray(serial.ranks))
        np.testing.assert_allclose(
            np.asarray(_tt.static_tt_reconstruct(member)),
            np.asarray(_tt.static_tt_reconstruct(serial)), atol=1e-5,
        )
        # cropping the padding reproduces the reconstruction exactly
        tt = _tt.static_tt_crop(member)
        np.testing.assert_allclose(
            np.asarray(_tt.tt_reconstruct(tt)),
            np.asarray(_tt.static_tt_reconstruct(member)), atol=1e-5,
        )


def test_svd_batched_matches_serial():
    from repro.core.svd import svd, svd_batched, svd_reconstruct
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((3, 24, 10)).astype(np.float32))
    for method, impl in [("library", "unblocked"), ("two_phase", "unblocked"),
                         ("two_phase", "blocked")]:
        rb = svd_batched(a, method=method, hbd_impl=impl, panel=8)
        for k in range(3):
            rs = svd(a[k], method=method, hbd_impl=impl, panel=8)
            np.testing.assert_allclose(np.asarray(rb.s[k]), np.asarray(rs.s),
                                       atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(svd_reconstruct(
                    type(rs)(rb.u[k], rb.s[k], rb.vt[k]))),
                np.asarray(a[k]), atol=1e-3,
            )


def test_hbd_batched_matches_serial():
    from repro.core.hbd import (
        householder_bidiagonalize, householder_bidiagonalize_batched,
    )
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.standard_normal((3, 12, 8)).astype(np.float32))
    ub, bb, vbt = householder_bidiagonalize_batched(a)
    for k in range(3):
        u, b, vt = householder_bidiagonalize(a[k])
        np.testing.assert_allclose(np.asarray(ub[k]), np.asarray(u),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(bb[k]), np.asarray(b),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(vbt[k]), np.asarray(vt),
                                   atol=1e-5)


def test_fedttd_roundtrip_batched_matches_serial(rng):
    from repro.core.comm_compress import CommCompressionConfig, fedttd_roundtrip
    cfg = CommCompressionConfig(eps=0.05, max_rank=16, min_size=64)
    base = rng.standard_normal((32, 24)).astype(np.float32)
    deltas = [jnp.asarray(base + 0.1 * rng.standard_normal((32, 24))
                          .astype(np.float32)) for _ in range(3)]
    avg_b, res_b, ratio_b = fedttd_roundtrip(deltas, cfg, plan="batched")
    avg_s, res_s, ratio_s = fedttd_roundtrip(deltas, cfg, plan="serial")
    np.testing.assert_allclose(np.asarray(avg_b), np.asarray(avg_s),
                               atol=1e-5)
    for rb_, rs_ in zip(res_b, res_s):
        np.testing.assert_allclose(np.asarray(rb_), np.asarray(rs_),
                                   atol=1e-5)
    assert ratio_b == pytest.approx(ratio_s)
