"""Fused decode driver + continuous-batching engine.

Three layers of guarantees:

  * the fused ``lax.scan`` driver is token-for-token identical to the
    python one-step-per-token loop — across every family in the zoo, with
    dense AND TT-native weights, including int8-quantized cores with fused
    in-kernel dequant (the scan changes WHERE the loop runs, not what it
    computes);
  * the slot/length-masked decode contract is backwards compatible: a
    legacy scalar-``pos`` cache decodes identically to the per-slot one;
  * continuous batching is exact, not approximate: staggered requests with
    unequal prompt/gen lengths produce the same tokens as isolated runs
    (slot admission resets state completely; validity masks keep cache
    rows independent).  Encdec requests carry encoder input: admission
    runs the encode and fills the slot's cross-attention memory rows, and
    a recycled slot never leaks a previous occupant's memory.  MoE is
    excluded from the staggered case only — expert-capacity routing
    couples batch rows by design — but holds fused-vs-python parity like
    every other family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import Engine, generate
from repro.models.registry import build

FAMILY_ARCHS = [
    "gemma3-1b",              # transformer (dense)
    "seamless-m4t-large-v2",  # encdec
    "mamba2-1.3b",            # ssm
    "recurrentgemma-2b",      # hybrid
    "olmoe-1b-7b",            # moe expert banks
]


def _model_and_params(arch, weights="dense"):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    if weights == "dense":
        return cfg, model, model.init(jax.random.PRNGKey(0))
    from repro.core import (
        CompressionPolicy, TTCompressor, spectral_decay_pytree,
    )
    from repro.models import common as model_common
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=0.2, min_size=8192))
    payload, _ = comp.compress(params)
    quant = "int8" if weights == "tt-int8" else None
    return cfg, model, model_common.tt_native_params(
        payload, family=cfg.family, quant=quant)


def _assert_drivers_agree(cfg, model, params, b=2, plen=4, gen=5):
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (b, plen), np.int32)
    py = generate(model, params, prompts, gen, driver="python")
    fu = generate(model, params, prompts, gen, driver="fused")
    np.testing.assert_array_equal(py["gen"], fu["gen"])
    d = np.abs(np.asarray(py["prompt_logits"], np.float32)
               - np.asarray(fu["prompt_logits"], np.float32)).max()
    scale = max(np.abs(np.asarray(py["prompt_logits"])).max(), 1e-6)
    assert d <= 1e-3 * scale + 1e-5, (d, scale)


def test_fused_matches_python_transformer():
    """Fast lane: dense transformer parity (the CI-visible smoke)."""
    _assert_drivers_agree(*_model_and_params("qwen1.5-0.5b"))


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_fused_matches_python_dense(arch):
    _assert_drivers_agree(*_model_and_params(arch))


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_fused_matches_python_tt(arch):
    _assert_drivers_agree(*_model_and_params(arch, weights="tt"))


def test_fused_matches_python_tt_int8():
    """Quantized cores change the numbers, not the drivers: fused and
    python loops must stay token-identical when every TT leaf is int8 with
    in-kernel dequant (fast lane — one small dense transformer)."""
    _assert_drivers_agree(*_model_and_params("qwen1.5-0.5b",
                                             weights="tt-int8"))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma3-1b", "olmoe-1b-7b"])
def test_fused_matches_python_tt_int8_families(arch):
    """Quantized parity on the two distinct serving paths: the lead-table
    scan (gemma3) and the expert-batched chain (olmoe)."""
    _assert_drivers_agree(*_model_and_params(arch, weights="tt-int8"))


def test_scalar_pos_cache_still_decodes():
    """Legacy contract: a scalar-``pos`` cache (lockstep serving) decodes
    identically to the per-slot (B,) one at equal positions."""
    cfg, model, params = _model_and_params("qwen1.5-0.5b")
    b = 2
    cache_slot = model.init_cache(b, 8)
    cache_scal = cache_slot._replace(pos=jnp.zeros((), jnp.int32))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab_size, (b, 3), np.int32)
    for i in range(toks.shape[1]):
        t = jnp.asarray(toks[:, i:i + 1])
        l1, cache_slot = model.decode_step(params, cache_slot, t)
        l2, cache_scal = model.decode_step(params, cache_scal, t)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32),
        atol=1e-5, rtol=1e-5,
    )
    assert cache_slot.pos.shape == (b,) and cache_scal.pos.shape == ()


def _staggered_vs_isolated(arch, slots, reqs_spec, chunk_steps=3,
                           temperature=0.0, top_k=None, weights="dense"):
    cfg, model, params = _model_and_params(arch, weights=weights)
    rng = np.random.default_rng(2)
    eng = Engine(model, params, slots=slots, max_len=24,
                 chunk_steps=chunk_steps, temperature=temperature,
                 top_k=top_k)
    with_src = model.admit_memory is not None
    reqs = []
    for seed, (plen, gen) in enumerate(reqs_spec):
        p = rng.integers(0, cfg.vocab_size, (plen,), np.int32)
        src = None
        if with_src:       # encdec: every request carries its own source
            slen = 3 + int(rng.integers(0, cfg.frontend_len - 3))
            src = rng.integers(0, cfg.vocab_size, (slen,), np.int32)
        reqs.append((eng.submit(p, gen, src_tokens=src, seed=seed),
                     p, gen, src, seed))
    done = {c.uid: c for c in eng.run()}
    assert sorted(done) == sorted(uid for uid, *_ in reqs)
    for uid, p, gen, src, seed in reqs:
        iso = generate(
            model, params, p[None, :], gen, driver="fused",
            src_tokens=None if src is None else src[None, :],
            temperature=temperature, top_k=top_k, seed=seed,
        )
        np.testing.assert_array_equal(
            done[uid].tokens, iso["gen"][0],
            err_msg=f"{arch} uid={uid} plen={len(p)} gen={gen}",
        )
    # occupancy accounting stays within the pool budget
    assert 0 < eng.slot_steps <= eng.steps * eng.slots


REQS = [(5, 4), (3, 7), (9, 3), (2, 5), (6, 6)]


def test_continuous_matches_isolated_transformer():
    """Staggered heterogeneous requests == isolated runs (token-exact)."""
    _staggered_vs_isolated("qwen1.5-0.5b", slots=2, reqs_spec=REQS)


def test_continuous_matches_isolated_tt_int8():
    """ISSUE 7 acceptance: staggered == isolated must hold with QUANTIZED
    cores too — the engine and the isolated run share the same int8 params,
    so per-tile dequant cannot depend on which slot/step a token lands in."""
    _staggered_vs_isolated("qwen1.5-0.5b", slots=2, reqs_spec=REQS[:4],
                           weights="tt-int8")


def test_continuous_matches_isolated_sampled():
    """The staggered == isolated guarantee survives stochastic sampling:
    per-request base keys advance with slot-LOCAL progress only, so a
    request's sample stream is independent of its slot and neighbours."""
    _staggered_vs_isolated("qwen1.5-0.5b", slots=2, reqs_spec=REQS[:4],
                           temperature=0.9, top_k=64)


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch", ["gemma3-1b", "seamless-m4t-large-v2", "mamba2-1.3b",
             "recurrentgemma-2b"]
)
def test_continuous_matches_isolated_families(arch):
    """Slot admission fully resets recurrent/conv/KV state per family
    (stale neighbours never leak into a readmitted slot).  encdec requests
    carry per-request encoder input — admission runs the encode and the
    staggered slot must still match the isolated run token-for-token."""
    _staggered_vs_isolated(arch, slots=2, reqs_spec=REQS[:4])


def _encdec_setup(max_len=16, slots=1, chunk_steps=3):
    cfg, model, params = _model_and_params("seamless-m4t-large-v2")
    eng = Engine(model, params, slots=slots, max_len=max_len,
                 chunk_steps=chunk_steps)
    return cfg, model, params, eng


def test_encdec_engine_memory_at_admission():
    """The PR 4 hole, closed: an encdec request's cross-attention memory is
    computed at admission and lives in its slot — the engine's tokens match
    the isolated memory-conditioned run exactly, and differ from the
    zero-memory decode the old engine produced."""
    cfg, model, params, eng = _encdec_setup(slots=2)
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, (3,), np.int32)
    src = rng.integers(0, cfg.vocab_size, (6,), np.int32)
    uid = eng.submit(p, 5, src_tokens=src)
    done = {c.uid: c for c in eng.run()}
    iso = generate(model, params, p[None], 5, driver="fused",
                   src_tokens=src[None])
    np.testing.assert_array_equal(done[uid].tokens, iso["gen"][0])
    iso_zero = generate(model, params, p[None], 5, driver="fused")
    assert not np.array_equal(iso["gen"][0], iso_zero["gen"][0]), (
        "encoder memory had no effect on the decode — the admission "
        "encode is not reaching cross-attention"
    )


def test_recycled_slot_no_stale_memory():
    """Satellite: a slot reused after an encdec-with-memory occupant must
    not leak stale mem_k/mem_v into a token-only request — asserted at the
    LOGIT level, not just tokens (greedy argmax can mask small leaks)."""
    cfg, model, params, eng = _encdec_setup(slots=1)
    rng = np.random.default_rng(6)
    src = rng.integers(0, cfg.vocab_size, (7,), np.int32)
    p1 = rng.integers(0, cfg.vocab_size, (4,), np.int32)
    p2 = rng.integers(0, cfg.vocab_size, (3,), np.int32)
    u1 = eng.submit(p1, 4, src_tokens=src)   # occupies THE slot first
    u2 = eng.submit(p2, 4)                   # token-only, recycles the slot
    done = {c.uid: c for c in eng.run()}
    iso = generate(model, params, p2[None], 4, driver="fused")
    np.testing.assert_array_equal(done[u2].tokens, iso["gen"][0])
    a = np.asarray(done[u2].prompt_logits, np.float32)
    b = np.asarray(iso["prompt_logits"][0], np.float32)
    scale = max(np.abs(b).max(), 1e-6)
    assert np.abs(a - b).max() <= 1e-3 * scale + 1e-5, (
        "stale cross-attention memory leaked into the recycled slot"
    )
    assert u1 in done


def test_engine_rejects_oversized_request():
    cfg, model, params = _model_and_params("qwen1.5-0.5b")
    eng = Engine(model, params, slots=2, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(np.zeros((6,), np.int32), 4)


def test_engine_capacity_error_covers_encoder_positions():
    """Satellite: the capacity error must report the encoder-side limit
    too, not just max_len, when the request carries encoder input."""
    cfg, model, params, eng = _encdec_setup(max_len=16)
    ok_prompt = np.zeros((3,), np.int32)
    too_long_src = np.zeros((cfg.frontend_len + 1,), np.int32)
    with pytest.raises(ValueError) as ei:
        eng.submit(ok_prompt, 4, src_tokens=too_long_src)
    msg = str(ei.value)
    assert "encoder" in msg and str(cfg.frontend_len) in msg
    # the decoder-side overflow message still names the pool bound
    with pytest.raises(ValueError, match="decoder"):
        eng.submit(np.zeros((20,), np.int32), 4)


def test_engine_rejects_src_on_token_only_family():
    cfg, model, params = _model_and_params("qwen1.5-0.5b")
    eng = Engine(model, params, slots=2, max_len=16)
    with pytest.raises(ValueError, match="token-only"):
        eng.submit(np.zeros((3,), np.int32), 4,
                   src_tokens=np.zeros((4,), np.int32))


def test_engine_more_requests_than_slots():
    """Queue drains through slot recycling (admission into retired slots)."""
    cfg, model, params = _model_and_params("qwen1.5-0.5b")
    rng = np.random.default_rng(3)
    eng = Engine(model, params, slots=1, max_len=16, chunk_steps=2)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, (3,), np.int32), 3)
            for _ in range(3)]
    done = {c.uid for c in eng.run()}
    assert done == set(uids)


def test_per_request_sampling_params_ride_slots():
    """Per-request sampling is honored PER SLOT: greedy, high-temperature,
    and temperature+top-k requests decode concurrently in one pool and
    each matches its own isolated run token-for-token — in BOTH admission
    modes (the per-slot temp/topk rows ride ``GenState`` either way)."""
    cfg, model, params = _model_and_params("qwen1.5-0.5b")
    rng = np.random.default_rng(7)
    specs = [                     # (plen, gen, temperature, top_k, seed)
        (4, 5, 0.0, None, 0),     # greedy rides next to sampled neighbours
        (3, 6, 1.1, None, 5),
        (5, 4, 0.7, 16, 9),
        (2, 6, 0.9, 32, 3),
    ]
    prompts = [rng.integers(0, cfg.vocab_size, (p,), np.int32)
               for p, *_ in specs]
    for admission in ("scan", "boundary"):
        eng = Engine(model, params, slots=2, max_len=24, chunk_steps=3,
                     admission=admission)
        uids = [eng.submit(prompts[i], g, seed=s, temperature=t, top_k=k)
                for i, (_, g, t, k, s) in enumerate(specs)]
        done = {c.uid: c for c in eng.run()}
        for i, (_, g, t, k, s) in enumerate(specs):
            iso = generate(model, params, prompts[i][None], g,
                           driver="fused", temperature=t, top_k=k, seed=s)
            np.testing.assert_array_equal(
                done[uids[i]].tokens, iso["gen"][0],
                err_msg=f"admission={admission} spec={specs[i]}",
            )


def test_scan_and_boundary_admission_agree():
    """The in-scan device-resident queue is an OPTIMIZATION, not a new
    semantics: the same staggered request stream produces byte-identical
    completions (tokens AND prompt logits) under both admission modes."""
    cfg, model, params = _model_and_params("qwen1.5-0.5b")
    rng = np.random.default_rng(8)
    reqs = [(rng.integers(0, cfg.vocab_size, (p,), np.int32), g)
            for p, g in REQS]
    outs = {}
    for admission in ("scan", "boundary"):
        eng = Engine(model, params, slots=2, max_len=24, chunk_steps=3,
                     admission=admission)
        uids = [eng.submit(p, g, seed=i) for i, (p, g) in enumerate(reqs)]
        done = {c.uid: c for c in eng.run()}
        outs[admission] = [done[u] for u in uids]
        assert eng.admission == admission
    for a, b in zip(outs["scan"], outs["boundary"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(
            np.asarray(a.prompt_logits), np.asarray(b.prompt_logits))


def test_scan_admission_rejected_for_encdec():
    """Admission-mode guard: encdec admission runs the encode host-side,
    so an explicit ``admission='scan'`` must fail fast (auto = boundary)."""
    cfg, model, params = _model_and_params("seamless-m4t-large-v2")
    with pytest.raises(ValueError, match="boundary"):
        Engine(model, params, slots=2, max_len=16, admission="scan")
    eng = Engine(model, params, slots=2, max_len=16, admission="auto")
    assert eng.admission == "boundary"
