"""Fault tolerance: serving supervision, numeric quarantine, checkpoint
integrity, and the control-plane pieces that back them.

Three layers, matching the failure model:

  * control-plane units (no model): RestartPolicy's injectable sleep keeps
    the FULL exponential delay while tests run instantly; StragglerMonitor
    and ElasticPlan edge cases; FaultPlan determinism.
  * checkpoint integrity (tiny arrays, no model): sha256 verification
    catches bit-flips (naming the leaf) and truncation; ``verify=False``
    opts out; stale ``*.tmp`` dirs from crashed saves are cleaned.
  * serving plane (reduced model): a crashed replica worker fails over its
    never-admitted tickets (parity-exact on the new replica), completes
    admitted ones with retryable ``ReplicaLost``, restarts under the
    backoff policy, and surfaces its stored exception in ``stats()``;
    NaN-poisoned requests are quarantined with ``NumericFault`` while
    sibling slots keep staggered == isolated parity; garbage submissions
    are rejected with typed ``InvalidRequest`` before placement.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    CheckpointCorrupt, CheckpointManager, clean_stale_tmp,
)
from repro.configs import get_config
from repro.launch.engine import Engine, InvalidRequest, generate
from repro.launch.router import (
    DEAD, LIVE, NoLiveReplicas, NumericFault, ReplicaLost, Router,
)
from repro.models.registry import build
from repro.runtime.elastic import plan_mesh
from repro.runtime.fault_tolerance import (
    FaultPlan, InjectedFault, RestartPolicy, StragglerMonitor,
    TrainingFailure,
)

ARCH = "qwen1.5-0.5b"


# ---------------------------------------------------------------------------
# control-plane units (no model)
# ---------------------------------------------------------------------------

def test_restart_policy_full_backoff_with_injected_sleep():
    """The injectable sleep records the FULL exponential delays — the old
    ``min(delay, 0.01)`` test hack capped production backoff at 10ms."""
    slept = []
    policy = RestartPolicy(max_restarts=6, backoff_s=1.0, backoff_factor=2.0,
                           max_backoff_s=8.0, sleep=slept.append)
    calls = {"n": 0}

    def loop(start):
        if calls["n"] < 5:
            calls["n"] += 1
            raise TrainingFailure(calls["n"], calls["n"], "boom")
        return 99

    assert policy.run(loop, log=lambda *a: None) == 99
    assert slept == [1.0, 2.0, 4.0, 8.0, 8.0]   # exact, capped at max


def test_restart_policy_backoff_helper():
    policy = RestartPolicy(backoff_s=0.5, backoff_factor=3.0,
                           max_backoff_s=10.0)
    assert [policy.backoff(i) for i in (1, 2, 3, 4)] == [0.5, 1.5, 4.5, 10.0]


def test_straggler_monitor_zero_observations():
    mon = StragglerMonitor()
    assert mon.cordon_candidates() == []        # nothing observed, no hosts
    assert mon.observe(0.1) is False            # first sample seeds the EWMA
    assert mon.cordon_candidates(threshold=1) == []


def test_straggler_monitor_cordons_repeat_offender():
    mon = StragglerMonitor(sigma_k=3.0, min_steps=5)
    for i in range(45):
        t = 0.1 + 0.001 * (i % 3)
        if i in (20, 30, 40):
            t = 2.0                             # same host straggles 3x
        host = "bad-host" if i in (20, 30, 40) else f"host{i % 4}"
        mon.observe(t, host=host)
    assert mon.cordon_candidates(threshold=3) == ["bad-host"]
    assert mon.cordon_candidates(threshold=4) == []


def test_elastic_plan_below_model_axis():
    """Pool smaller than one model group: TP degree halves until it fits;
    the mesh still builds from an explicit device list."""
    plan = plan_mesh(available=2, model_parallel=4)
    assert plan.mesh_shape == (1, 2) and plan.dropped_devices == 0

    plan1 = plan_mesh(available=1, model_parallel=4, prev_shape=(1, 4))
    assert plan1.mesh_shape == (1, 1) and plan1.changed
    mesh = plan1.build(devices=jax.devices("cpu")[:1])
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 1)


def test_fault_plan_seeded_determinism():
    a = FaultPlan.seeded(3, replicas=4, requests=16, crashes=1, stalls=2)
    b = FaultPlan.seeded(3, replicas=4, requests=16, crashes=1, stalls=2)
    assert (a.crash_at, a.stall_at, a.poison) == (b.crash_at, b.stall_at,
                                                  b.poison)
    assert set(a.crash_at).isdisjoint(a.stall_at)   # distinct replicas
    assert a.counts()["crashes"] == 1 and a.counts()["stalls"] == 2


def test_fault_plan_hook_fires_once():
    slept = []
    plan = FaultPlan(crash_at={0: 2}, stall_at={1: (1, 0.5)},
                     sleep=slept.append)
    h0, h1 = plan.hook_for(0), plan.hook_for(1)
    h0(0)
    h0(1)                                        # below threshold: nothing
    with pytest.raises(InjectedFault):
        h0(2)
    h0(3)                                        # fired already: no re-raise
    h1(1)
    h1(5)
    assert slept == [0.5]                        # stall slept exactly once
    assert plan.fired() == {"crashes": 1, "stalls": 1}


# ---------------------------------------------------------------------------
# checkpoint integrity (tiny arrays, no model)
# ---------------------------------------------------------------------------

@pytest.fixture
def ckpt_state(rng):
    return {"w": rng.standard_normal((12, 12)).astype(np.float32),
            "b": rng.standard_normal((6,)).astype(np.float32)}


def test_checkpoint_bitflip_names_leaf(tmp_path, ckpt_state):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, ckpt_state)
    restored, _ = mgr.restore(ckpt_state)       # clean shard verifies
    assert np.array_equal(np.asarray(restored["w"]), ckpt_state["w"])

    # rewrite the shard with one array zeroed: a VALID zip with wrong
    # content — only the manifest sha256 can catch this
    shard = tmp_path / "step_000001" / "shard_0.npz"
    data = dict(np.load(shard))
    data["w"] = np.zeros_like(data["w"])
    np.savez(shard, **data)
    with pytest.raises(CheckpointCorrupt, match="'w'"):
        mgr.restore(ckpt_state)
    # opt-out loads the corrupt shard anyway (operator's escape hatch)
    restored, _ = mgr.restore(ckpt_state, verify=False)
    assert not np.any(np.asarray(restored["w"]))


def test_checkpoint_truncation_caught(tmp_path, ckpt_state):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(2, ckpt_state)
    shard = tmp_path / "step_000002" / "shard_0.npz"
    raw = shard.read_bytes()
    shard.write_bytes(raw[: len(raw) // 2])
    with pytest.raises(CheckpointCorrupt):
        mgr.restore(ckpt_state)


def test_checkpoint_missing_leaf_caught(tmp_path, ckpt_state):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, ckpt_state)
    shard = tmp_path / "step_000003" / "shard_0.npz"
    data = dict(np.load(shard))
    del data["b"]
    np.savez(shard, **data)
    with pytest.raises(CheckpointCorrupt, match="'b'"):
        mgr.restore(ckpt_state)


def test_checkpoint_stale_tmp_cleaned(tmp_path, ckpt_state):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(4, ckpt_state)
    stale = tmp_path / "step_000009.tmp"
    stale.mkdir()
    (stale / "shard_0.npz").write_bytes(b"partial")
    mgr2 = CheckpointManager(str(tmp_path))     # open detects + cleans
    assert str(stale) in mgr2.cleaned_tmp and not stale.exists()
    assert mgr2.latest_step() == 4              # committed step untouched
    assert clean_stale_tmp(str(tmp_path)) == []


# ---------------------------------------------------------------------------
# serving plane (reduced model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_engine(model, params):
    return Engine(model, params, slots=2, max_len=24, chunk_steps=3)


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (n,), np.int32)


def _fast_router(model, params, n, **kw):
    """Router with no-op restart sleep (full delays recorded, zero wall
    clock) and a tight supervision cadence."""
    slept = []
    kw.setdefault("restart_policy",
                  RestartPolicy(max_restarts=3, backoff_s=0.2,
                                max_backoff_s=1.0, sleep=slept.append))
    kw.setdefault("engine_factory", lambda old: _mk_engine(model, params))
    kw.setdefault("supervise_interval", 0.01)
    router = Router([_mk_engine(model, params) for _ in range(n)],
                    queue_depth=8, **kw)
    return router, slept


def test_validate_rejects_garbage_typed(setup):
    """Oversized/garbage submissions fail with InvalidRequest (a
    ValueError subclass → HTTP 400) BEFORE consuming a queue slot."""
    cfg, model, params = setup
    eng = _mk_engine(model, params)
    ok = _prompt(cfg, 4)
    with pytest.raises(InvalidRequest):
        eng.validate(ok, 0, None, None, None)              # gen < 1
    with pytest.raises(InvalidRequest):
        eng.validate(ok, 64, None, None, None)             # > max_len
    with pytest.raises(InvalidRequest, match="must be in"):
        eng.validate(np.asarray([0, cfg.vocab_size]), 3, None, None, None)
    with pytest.raises(InvalidRequest):
        eng.validate(np.asarray([-1, 2]), 3, None, None, None)
    with pytest.raises(InvalidRequest, match="integral"):
        eng.validate(np.asarray([0.5, 1.0]), 3, None, None, None)
    with pytest.raises(InvalidRequest):
        eng.validate("not tokens", 3, None, None, None)
    with pytest.raises(InvalidRequest):
        eng.validate(ok, 2.5, None, None, None)            # non-int gen
    assert issubclass(InvalidRequest, ValueError)


def test_crash_failover_parity_and_restart(setup):
    """Replica worker dies mid-trace: never-admitted tickets fail over and
    match isolated runs token-for-token; admitted ones get retryable
    ReplicaLost (at-most-once — no silent re-decode); the replica
    restarts under the policy and capacity returns to full."""
    cfg, model, params = setup
    router, slept = _fast_router(model, params, 2)
    router.replicas[0].fault_hook = FaultPlan(crash_at={0: 1}).hook_for(0)
    router.start()
    try:
        reqs = [(_prompt(cfg, 3 + i % 3, seed=i), 4 + i % 3, i)
                for i in range(6)]
        tickets = [router.submit(p, g, seed=s) for p, g, s in reqs]
        done, lost = {}, []
        for i, t in enumerate(tickets):
            try:
                done[i] = t.result(timeout=120).tokens.tolist()
            except ReplicaLost:
                lost.append(i)
        assert done and lost, (sorted(done), lost)   # crash split the trace
        for i, toks in done.items():
            p, g, s = reqs[i]
            iso = generate(model, params, p[None], g, driver="fused",
                           seed=s)["gen"][0].tolist()
            assert toks == iso, f"request {i} diverged after failover"
        deadline = time.monotonic() + 60
        while router.live_replicas() < 2 and time.monotonic() < deadline:
            time.sleep(0.02)
        assert router.live_replicas() == 2
        st = router.stats()
        assert st["replicas"][0]["restarts"] == 1
        assert slept and slept[0] == 0.2         # policy delay, not slept-for-real
        # recovered replica serves again, parity-exact
        p, g, s = _prompt(cfg, 4, seed=99), 5, 99
        toks = router.submit(p, g, seed=s).result(timeout=120).tokens.tolist()
        iso = generate(model, params, p[None], g, driver="fused",
                       seed=s)["gen"][0].tolist()
        assert toks == iso
    finally:
        router.close()


def test_poisoned_request_quarantined_siblings_exact(setup):
    """NaN logits on one slot: that request fails NumericFault; requests
    sharing the batch keep staggered == isolated parity."""
    cfg, model, params = setup
    poison_tok = cfg.vocab_size - 1
    base = model.decode_step

    def poisoned(p, c, t):
        import jax.numpy as jnp
        logits, cache = base(p, c, t)
        hit = jnp.any(t == poison_tok, axis=-1)
        return jnp.where(hit[:, None], jnp.asarray(np.nan, logits.dtype),
                         logits), cache

    pmodel = dataclasses.replace(model, decode_step=poisoned)
    router = Router([Engine(pmodel, params, slots=2, max_len=24,
                            chunk_steps=3)], queue_depth=8)
    router.start()
    try:
        prompts = [_prompt(cfg, 3, seed=i) % (cfg.vocab_size - 1)
                   for i in range(3)]
        prompts[1][-1] = poison_tok
        tickets = [router.submit(p, 5, seed=i)
                   for i, p in enumerate(prompts)]
        with pytest.raises(NumericFault):
            tickets[1].result(timeout=120)
        for i in (0, 2):
            toks = tickets[i].result(timeout=120).tokens.tolist()
            iso = generate(pmodel, params, prompts[i][None], 5,
                           driver="fused", seed=i)["gen"][0].tolist()
            assert toks == iso, f"sibling {i} diverged next to poison"
        # the quarantined slot was freed: the engine serves new work
        p = _prompt(cfg, 4, seed=7) % (cfg.vocab_size - 1)
        assert router.submit(p, 4, seed=7).result(
            timeout=120).tokens is not None
    finally:
        router.close()


def test_dead_worker_surfaced_and_no_live_replicas(setup):
    """With restarts exhausted, a dead replica stays DEAD with its stored
    exception in stats() (close() doesn't swallow it), and submit raises
    NoLiveReplicas."""
    cfg, model, params = setup
    router, _ = _fast_router(
        model, params, 1,
        restart_policy=RestartPolicy(max_restarts=0, sleep=lambda s: None))
    router.replicas[0].fault_hook = FaultPlan(crash_at={0: 0}).hook_for(0)
    router.start()
    try:
        t = router.submit(_prompt(cfg, 3), 4, seed=0)
        with pytest.raises(ReplicaLost):
            t.result(timeout=60)
        deadline = time.monotonic() + 30
        while (router.replicas[0].state != DEAD
               and time.monotonic() < deadline):
            time.sleep(0.02)
        st = router.stats()
        assert st["live_replicas"] == 0
        assert st["replicas"][0]["state"] == DEAD
        assert "InjectedFault" in st["replicas"][0]["error"]
        with pytest.raises(NoLiveReplicas):
            router.submit(_prompt(cfg, 3), 4, seed=1)
        assert router.retry_after() >= 1
    finally:
        router.close()
    # the exception survives close() — join on the corpse isn't silent
    assert "InjectedFault" in router.stats()["replicas"][0]["error"]
