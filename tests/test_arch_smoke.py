"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finiteness, plus a decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import NAME_TO_MODULE, get_config
from repro.models.registry import build

ARCHS = list(NAME_TO_MODULE)


def _make_batch(m, cfg, b, s, key):
    spec = m.train_batch_spec(b, s)
    out = {}
    for k, v in spec.items():
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(key, v.shape, 0, cfg.vocab_size)
        else:
            out[k] = jax.random.normal(key, v.shape, jnp.float32).astype(v.dtype)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _make_batch(m, cfg, 2, 64, jax.random.PRNGKey(1))
    loss, metrics = m.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    # loss should be near ln(V) at init (uniform predictions)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_changes_loss(arch):
    cfg = get_config(arch).reduced()
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _make_batch(m, cfg, 2, 32, jax.random.PRNGKey(1))

    def loss_fn(p):
        return m.loss_fn(p, batch)[0]

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = np.sqrt(sum(
        float(jnp.sum(g.astype(jnp.float32) ** 2))
        for g in jax.tree.leaves(grads)
    ))
    assert np.isfinite(gnorm) and gnorm > 0
    lr = 0.5
    params2 = jax.tree.map(
        lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )
    loss1 = float(loss_fn(params2))
    assert loss1 < float(loss0)  # one SGD step on the same batch improves


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = 2
    cache = m.init_cache(b, 16)
    logits, cache2 = m.decode_step(
        params, cache, jnp.zeros((b, 1), jnp.int32)
    )
    assert logits.shape == (b, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma3-1b", "mamba2-1.3b",
                                  "recurrentgemma-2b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode must agree with the parallel forward pass —
    the KV-cache/state path is numerically consistent with training."""
    cfg = get_config(arch).reduced()
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    # parallel forward logits at the last position
    batch = {"tokens": toks}
    ref = np.asarray(m.prefill(params, batch), np.float32)
    # sequential decode
    cache = m.init_cache(b, s + 2)
    logits = None
    for i in range(s):
        logits, cache = m.decode_step(params, cache, toks[:, i:i + 1])
    got = np.asarray(logits, np.float32)
    # bf16 compute: tolerances are loose but the argmax must agree
    np.testing.assert_allclose(got, ref, atol=0.15, rtol=0.05)
    np.testing.assert_array_equal(got.argmax(-1), ref.argmax(-1))
