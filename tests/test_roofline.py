"""Unit tests for the roofline HLO walker — the §Perf measurement tool."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import hlo_walk as hw


@pytest.fixture(autouse=True)
def _restore_pod_size():
    old = hw.POD_SIZE
    yield
    hw.set_pod_size(old)


# ---------------------------------------------------------------------------
# replica-group crossing classification (exact iota materialization)
# ---------------------------------------------------------------------------

class _FakeIota:
    """Mimics the regex match object interface for _iota_crosses."""
    def __init__(self, g, s, dims, perm=None):
        self._g = [None, str(g), str(s), ",".join(map(str, dims)),
                   ",".join(map(str, perm)) if perm else None]

    def group(self, i):
        return self._g[i]


def test_contiguous_groups_within_pod():
    hw.set_pod_size(256)
    # [64,8]<=[512]: groups of 8 contiguous devices — never cross a pod
    assert not hw._iota_crosses(_FakeIota(64, 8, [512]))


def test_full_span_crosses():
    hw.set_pod_size(256)
    # [1,512]<=[512]: one group over everything crosses pods
    assert hw._iota_crosses(_FakeIota(1, 512, [512]))


def test_stride_groups_cross_pods():
    hw.set_pod_size(256)
    # [256,2]<=[2,256]T(1,0): one device per pod in each group → crosses
    assert hw._iota_crosses(_FakeIota(256, 2, [2, 256], perm=[1, 0]))


def test_stride_groups_within_pod():
    hw.set_pod_size(256)
    # [16,16]<=[16,16]T(1,0) over 256 devices: strided but all inside pod 0
    # of a 512-device system?  group ids span 0..255 → within one pod.
    assert not hw._iota_crosses(_FakeIota(16, 16, [16, 16], perm=[1, 0]))


def test_mini_mesh_pod_size():
    hw.set_pod_size(32)   # 64-device mesh, 2 pods
    # [32,2]<=[2,4,8]T(2,1,0): pairs (i, i+32) — one device per pod, crosses
    assert hw._iota_crosses(_FakeIota(32, 2, [2, 4, 8], perm=[2, 1, 0]))
    # [8,8]<=[2,4,8]T(1,0,2): each group is 8 contiguous ids inside one pod
    assert not hw._iota_crosses(_FakeIota(8, 8, [2, 4, 8], perm=[1, 0, 2]))
    # [8,8]<=[64]: contiguous 8-groups stay inside a 32-wide pod
    assert not hw._iota_crosses(_FakeIota(8, 8, [64]))


# ---------------------------------------------------------------------------
# walker totals on a known program
# ---------------------------------------------------------------------------

def test_walk_counts_scan_trips():
    def f(x, w):
        def layer(h, _):
            return jax.nn.relu(h @ w), None
        h, _ = jax.lax.scan(layer, x, None, length=8)
        return h.sum()

    x = jnp.zeros((128, 256), jnp.float32)
    w = jnp.zeros((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    res = hw.walk(c.as_text())
    # 8 layers × 2·128·256·256 = 134.2 MFLOP; trip-count scaling must see
    # all 8 iterations (cost_analysis would count 1).
    expected = 8 * 2 * 128 * 256 * 256
    assert res.flops == pytest.approx(expected, rel=0.05)
    # traffic: ≥ reading w once per iteration (8×256KB) and ≤ 50× flops-
    # proportional upper bound sanity
    assert res.hbm_bytes > 8 * 256 * 256 * 4
    assert res.hbm_bytes < 100e6


def test_dus_fusion_charges_window_not_buffer():
    # stacking scan: each iteration writes one (128,256) slice into a
    # (16,128,256) buffer — traffic must scale with the window, not 16×.
    def f(x):
        def step(h, _):
            h = h * 1.5
            return h, h
        _, stack = jax.lax.scan(step, x, None, length=16)
        return stack

    x = jnp.zeros((128, 256), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    res = hw.walk(c.as_text())
    window = 128 * 256 * 4
    # generous bound: a few window-sized ops per iteration, NOT 16 buffers
    # copies of the carry are charged 2×result each; the key property is
    # that the stack write is window-sized (≈2×window), keeping the total
    # orders of magnitude below 16 full-buffer charges (16×16×window).
    assert res.hbm_bytes < 16 * 10 * window, res.hbm_bytes


def test_roofline_terms_finalize():
    from repro.roofline.analysis import Roofline
    r = Roofline(flops=197e12, hbm_bytes=819e9 / 2, ici_bytes=0.0,
                 dci_bytes=0.0, op_counts={}).finalize()
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.bottleneck == "compute"
    assert r.compute_fraction == pytest.approx(1.0)
