"""Integration tests: sharded train loop (8 virtual devices), failure →
restore recovery, FedTTD-in-the-loop, and a subprocess mini dry-run."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )


def test_sharded_train_loop_loss_decreases():
    """2x4 (data x model) mesh: loss on synthetic Markov data must drop."""
    r = _run("""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.models.registry import build
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.steps import TrainState, make_train_step
from repro.data.pipeline import DataConfig, SyntheticLM

cfg = get_config('qwen1.5-0.5b').reduced(vocab_size=256)
model = build(cfg)
mesh = make_host_mesh(model_parallel=4)
shd.set_mesh_axis_sizes(mesh)
opt = AdamW(learning_rate=cosine_schedule(2e-3, 5, 60))
step_fn = make_train_step(model, opt, batch_axes=('data',), microbatch=1)
data = SyntheticLM(DataConfig(vocab_size=256, seq_len=64, global_batch=8))
with mesh:
    params = model.init(jax.random.PRNGKey(0))
    specs = shd.param_specs(jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))), cfg)
    params = jax.device_put(params, shd.named(mesh, specs))
    state = TrainState(params=params, opt=opt.init(params))
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    losses = []
    for step in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state, m = jit_step(state, batch)
        losses.append(float(m['loss']))
print(json.dumps({'first': float(np.mean(losses[:5])),
                  'last': float(np.mean(losses[-5:]))}))
""")
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["last"] < out["first"] - 0.3, out


def test_failure_recovery_end_to_end(tmp_path):
    """Kill the loop at step 7, restart from checkpoint, final state matches
    an uninterrupted run bit-for-bit (determinism contract)."""
    r = _run(f"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.models.registry import build
from repro.optim.adamw import AdamW
from repro.train.steps import TrainState, make_train_step
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.checkpoint.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import RestartPolicy, simulate_failures

cfg = get_config('qwen1.5-0.5b').reduced(vocab_size=128)
model = build(cfg)
opt = AdamW(learning_rate=1e-3)
step_fn = jax.jit(make_train_step(model, opt, batch_axes=(), microbatch=1))
data = SyntheticLM(DataConfig(vocab_size=128, seq_len=32, global_batch=4))

def fresh_state():
    p = model.init(jax.random.PRNGKey(0))
    return TrainState(params=p, opt=opt.init(p))

def run(n_steps, mgr=None, inject=None):
    state = fresh_state()
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        state, man = mgr.restore(state)
        start = man['step'] + 1
    last_ckpt = start - 1
    for step in range(start, n_steps):
        if inject is not None:
            inject(step, resume_step=last_ckpt)
        batch = {{k: jnp.asarray(v) for k, v in data.batch_at(step).items()}}
        state, m = step_fn(state, batch)
        if mgr is not None and step % 3 == 2:
            mgr.save(step, state); mgr.wait(); last_ckpt = step
    return state

# uninterrupted reference
ref = run(12)
# interrupted run with restart policy
mgr = CheckpointManager(r'{tmp_path}', keep=5, async_save=False)
inject = simulate_failures({{7: 'simulated node failure'}})
policy = RestartPolicy(max_restarts=3, backoff_s=0.001)
def loop(start):
    run(12, mgr=mgr, inject=inject)
    return 12
policy.run(loop, log=lambda *a: None)
final = run(12, mgr=mgr)  # restore-only (already at 12): rebuild from ckpt
# compare a few leaves
ra = jax.tree.leaves(ref.params)[0]
fa = jax.tree.leaves(final.params)[0]
print(json.dumps({{'max_diff': float(jnp.abs(ra.astype(jnp.float32) - fa.astype(jnp.float32)).max())}}))
""", devices=1)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["max_diff"] == 0.0, out


def test_pod_sync_tt_shard_map():
    """pod_sync_tt inside shard_map over a 2-pod axis: averaged deltas match
    the dense pmean up to the TT ε, and residuals account for the gap."""
    r = _run("""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.comm_compress import CommCompressionConfig, pod_sync_tt

from repro.launch.mesh import make_mesh
mesh = make_mesh((2,), ('pod',))
cfg = CommCompressionConfig(eps=0.05, max_rank=32)
rng = np.random.default_rng(0)
lr = rng.standard_normal((64, 8)) @ rng.standard_normal((8, 64))
deltas = np.stack([lr + 0.01*rng.standard_normal((64,64)),
                   lr - 0.01*rng.standard_normal((64,64))]).astype(np.float32)

def f(d):
    avg, resid = pod_sync_tt(d[0], cfg, axis_name='pod')
    return avg[None], resid[None]

fm = shard_map(f, mesh=mesh, in_specs=(P('pod', None, None),),
               out_specs=(P('pod', None, None), P('pod', None, None)))
avg, resid = jax.jit(fm)(jnp.asarray(deltas))
dense = deltas.mean(0)
err = float(np.linalg.norm(np.asarray(avg[0]) - dense) / np.linalg.norm(dense))
# both pods computed the same average
pod_agree = float(np.abs(np.asarray(avg[0]) - np.asarray(avg[1])).max())
print(json.dumps({'err': err, 'agree': pod_agree}))
""", devices=2)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 0.06, out
    assert out["agree"] < 1e-5, out


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """End-to-end dryrun path on a small forced-device mesh (64 devices,
    8x8) — proves lower+compile+roofline integration without the full 512."""
    r = _run("""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=64'
import json, jax
import repro.launch.mesh as mesh_mod
# shrink the production mesh for the test
mesh_mod.make_production_mesh = lambda multi_pod=False: mesh_mod.make_mesh(
    (2, 4, 8) if multi_pod else (8, 8),
    ('pod', 'data', 'model') if multi_pod else ('data', 'model'))
import repro.launch.dryrun as dr
dr.make_production_mesh = mesh_mod.make_production_mesh
res = dr.lower_cell('qwen1.5-0.5b', 'train_4k', multi_pod=True)
print(json.dumps({'ok': res['memory']['peak_ok'],
                  'flops': res['roofline']['flops'],
                  'bottleneck': res['roofline']['bottleneck'],
                  'dci': res['roofline']['dci_bytes']}))
""", devices=64, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
    assert out["dci"] > 0            # pod axis actually shards & syncs


def test_moe_a2a_matches_gspmd_path():
    """shard_map all-to-all EP dispatch (opt_moe_a2a) must produce the same
    expert outputs as the GSPMD scatter path when capacity is not binding
    (dropping policy is per-model-slice under a2a — with slack none drop)."""
    r = _run("""
import json, dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch import sharding as shd
from repro.models import mlp as mlp_mod
from repro.models.registry import build

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ('data', 'model'))
shd.set_mesh_axis_sizes(mesh)
cfg = get_config('olmoe-1b-7b').reduced()      # 8 experts % model=4 == 0
cfg = dataclasses.replace(cfg, fsdp=True)
key = jax.random.PRNGKey(0)
p = mlp_mod.init_moe(key, cfg, layers=None)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32).astype(jnp.bfloat16)
with mesh:
    ref = jax.jit(lambda x, p: mlp_mod.moe_apply(x, p, cfg, 4.0))(x, p)
    a2a_cfg = cfg.with_opts(['moe_a2a'])
    out = jax.jit(lambda x, p: mlp_mod.moe_apply(x, p, a2a_cfg, 4.0))(x, p)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
print(json.dumps({'err': err}))
""", devices=8)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 5e-2, out
