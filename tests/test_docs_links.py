"""Docs link lint: every intra-repo markdown link must resolve.

Scans ``README.md`` and everything under ``docs/`` for markdown links and
images, and fails on any relative link whose target does not exist in the
checkout — the docs lint CI step runs exactly this file, so a doc that
names a moved/deleted file breaks the build instead of silently rotting.

Skipped on purpose: absolute URLs (http/https/mailto), pure in-page
anchors (``#section``), and links escaping the repo root (the CI badge
path).  Stdlib only — runnable standalone as
``python -m pytest tests/test_docs_links.py`` with no model imports.
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) and ![alt](target); stops at the first ')' — none of our
# docs use nested parens in link targets
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def _doc_files():
    files = [REPO / "README.md"]
    docs = REPO / "docs"
    if docs.is_dir():
        files.extend(sorted(docs.rglob("*.md")))
    return files


def _intra_repo_targets(md: Path):
    for raw in _LINK.findall(md.read_text()):
        target = raw.split("#", 1)[0]
        if not target:                        # pure anchor
            continue
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        resolved = (md.parent / target).resolve()
        try:
            resolved.relative_to(REPO)
        except ValueError:
            continue                          # escapes the repo (CI badge)
        yield raw, resolved


def test_docs_exist():
    """The operator docs this PR promises are actually in the tree."""
    assert (REPO / "docs" / "ARCHITECTURE.md").is_file()
    assert (REPO / "docs" / "SERVING.md").is_file()


def test_intra_repo_links_resolve():
    broken = []
    for md in _doc_files():
        for raw, resolved in _intra_repo_targets(md):
            if not resolved.exists():
                broken.append(f"{md.relative_to(REPO)}: ({raw}) -> "
                              f"{resolved.relative_to(REPO)}")
    assert not broken, "broken intra-repo links:\n  " + "\n  ".join(broken)
