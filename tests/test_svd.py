"""Two-phase SVD: values/bases vs library + Jacobi phase-2 oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bidiag_qr import bidiag_svd_values, jacobi_svd_values
from repro.core.hbd import bidiagonal_bands, householder_bidiagonalize
from repro.core.svd import sorting_basis, svd, svd_reconstruct

SHAPES = [(24, 24), (40, 16), (16, 40), (50, 30), (7, 13)]


@pytest.mark.parametrize("m,n", SHAPES)
def test_two_phase_values_match_library(rng, m, n):
    a = rng.standard_normal((m, n)).astype(np.float32)
    r = svd(jnp.asarray(a), method="two_phase")
    s_ref = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(np.asarray(r.s), s_ref, atol=2e-5 * s_ref[0])


@pytest.mark.parametrize("m,n", SHAPES)
def test_two_phase_reconstructs(rng, m, n):
    a = rng.standard_normal((m, n)).astype(np.float32)
    r = svd(jnp.asarray(a), method="two_phase")
    np.testing.assert_allclose(
        np.asarray(svd_reconstruct(r)), a, atol=5e-5 * np.sqrt(m * n)
    )


@pytest.mark.parametrize("m,n", [(64, 32), (96, 48)])
def test_blocked_hbd_svd(rng, m, n):
    a = rng.standard_normal((m, n)).astype(np.float32)
    r = svd(jnp.asarray(a), method="two_phase", hbd_impl="blocked", panel=16)
    s_ref = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(np.asarray(r.s), s_ref, atol=5e-5 * s_ref[0])
    np.testing.assert_allclose(
        np.asarray(svd_reconstruct(r)), a, atol=1e-4 * np.sqrt(m * n)
    )


def test_descending_order(rng):
    a = rng.standard_normal((30, 20)).astype(np.float32)
    r = svd(jnp.asarray(a), method="two_phase")
    s = np.asarray(r.s)
    assert np.all(np.diff(s) <= 1e-6)


def test_sorting_basis_permutes_consistently(rng):
    u = rng.standard_normal((8, 5)).astype(np.float32)
    s = np.array([3.0, 7.0, 1.0, 9.0, 5.0], np.float32)
    vt = rng.standard_normal((5, 6)).astype(np.float32)
    res = sorting_basis(jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt))
    # product invariant under permutation
    before = u @ np.diag(s) @ vt
    after = (
        np.asarray(res.u) @ np.diag(np.asarray(res.s)) @ np.asarray(res.vt)
    )
    np.testing.assert_allclose(after, before, atol=1e-5)
    assert np.all(np.diff(np.asarray(res.s)) <= 0)


def test_jacobi_oracle_matches_numpy(rng):
    a = rng.standard_normal((32, 20)).astype(np.float32)
    s = np.asarray(jacobi_svd_values(jnp.asarray(a)))
    s_ref = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(s, s_ref, atol=2e-5 * s_ref[0])


def test_phase2_on_hbd_bands(rng):
    """Full two-phase pipeline with the library-free diagonalizer."""
    a = rng.standard_normal((32, 20)).astype(np.float32)
    _, b, _ = householder_bidiagonalize(jnp.asarray(a), compute_uv=False)
    d, e = bidiagonal_bands(b)
    s = np.asarray(bidiag_svd_values(d, e))
    s_ref = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(s, s_ref, atol=2e-5 * s_ref[0])


def test_low_rank_exactness(rng):
    """Rank-3 matrix: two-phase SVD finds exactly 3 nonzero values."""
    u = rng.standard_normal((30, 3)).astype(np.float32)
    v = rng.standard_normal((3, 20)).astype(np.float32)
    a = u @ v
    r = svd(jnp.asarray(a), method="two_phase")
    s = np.asarray(r.s)
    assert s[3:].max() < 1e-4 * s[0]
