"""The wired front door: router placement, backpressure, deadlines, HTTP.

What the serving layer promises on top of the engine:

  * placement is DETERMINISTIC — least-outstanding, occupancy tiebreak,
    lowest index — a pure function of router counters, testable without
    ever starting a worker thread;
  * backpressure is a bounded queue: when every replica is at
    ``queue_depth`` the submit fails NOW (``QueueFull`` / HTTP 429), it
    never parks the request or hangs the client;
  * a deadline that expires mid-flight cancels the request AND frees its
    slot — the next request admits into the freed slot and still matches
    its isolated run;
  * the HTTP surface round-trips everything: non-streaming and SSE
    responses are token-for-token the isolated fused run (per-request
    sampling params ride the wire), errors map to 400/429/504, and a
    client disconnect propagates to ``Engine.cancel``.
"""

import http.client
import json
import socket
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import Engine, generate
from repro.launch.router import (
    DeadlineExpired, QueueFull, RequestCancelled, Router,
)
from repro.launch.server import serve_in_thread
from repro.models.registry import build

ARCH = "qwen1.5-0.5b"


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engines(model, params, n, slots=2, max_len=24, chunk_steps=3):
    return [Engine(model, params, slots=slots, max_len=max_len,
                   chunk_steps=chunk_steps) for _ in range(n)]


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (n,), np.int32)


# -- router (no workers needed) ---------------------------------------------

def test_router_places_deterministically(setup):
    """A seeded trace maps to replicas as a pure function of the
    outstanding counters: round-robin while balanced, least-loaded when
    not — byte-for-byte reproducible without starting any worker."""
    cfg, model, params = setup
    router = Router(_engines(model, params, 3), queue_depth=8)
    p = _prompt(cfg, 4)
    picks = [router.submit(p, 3).replica for _ in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]
    # a replica relieved of load (cancelled ticket) is preferred again
    t = router.submit(p, 3)           # -> replica 1 (outstanding 3,2,2)
    assert t.replica == 1
    router.cancel(t)                  # cancel is async; counter still held
    assert router.stats()["replicas"][1]["outstanding"] == 3


def test_router_queue_full_is_immediate(setup):
    """Backpressure, not a hang: with every replica at queue_depth the
    submit raises QueueFull right away (bounded admission queue)."""
    cfg, model, params = setup
    router = Router(_engines(model, params, 2), queue_depth=1)
    p = _prompt(cfg, 3)
    router.submit(p, 3)
    router.submit(p, 3)
    t0 = time.monotonic()
    with pytest.raises(QueueFull):
        router.submit(p, 3)
    assert time.monotonic() - t0 < 1.0


def test_router_rejects_bad_request_before_placement(setup):
    cfg, model, params = setup
    router = Router(_engines(model, params, 1), queue_depth=2)
    with pytest.raises(ValueError, match="temperature"):
        router.submit(_prompt(cfg, 3), 3, temperature=-1.0)
    assert router.stats()["replicas"][0]["outstanding"] == 0


def test_router_deadline_expiry_frees_slot(setup):
    """An expired request is cancelled between chunks and its SLOT comes
    back: the next request admits and still matches its isolated run."""
    cfg, model, params = setup
    router = Router(_engines(model, params, 1, slots=1), queue_depth=4)
    with router:
        doomed = router.submit(_prompt(cfg, 4, seed=1), 12, deadline=0.0)
        with pytest.raises(DeadlineExpired):
            doomed.result(timeout=60)
        p = _prompt(cfg, 3, seed=2)
        ok = router.submit(p, 4, seed=7)
        comp = ok.result(timeout=120)
    iso = generate(model, params, p[None], 4, driver="fused", seed=7)
    np.testing.assert_array_equal(comp.tokens, iso["gen"][0])
    stats = router.stats()["replicas"][0]
    assert stats["outstanding"] == 0 and stats["busy_slots"] == 0


def test_router_cancel_resolves_ticket(setup):
    cfg, model, params = setup
    router = Router(_engines(model, params, 1, slots=1), queue_depth=4)
    with router:
        t = router.submit(_prompt(cfg, 4), 12)
        router.cancel(t)
        with pytest.raises(RequestCancelled):
            t.result(timeout=60)


def test_routed_completions_match_isolated(setup):
    """Sanity across the whole router path: heterogeneous requests with
    per-request seeds spread over 2 replicas all match isolated runs."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    router = Router(_engines(model, params, 2), queue_depth=8)
    with router:
        reqs = []
        for i, (plen, gen) in enumerate([(5, 4), (3, 6), (2, 5), (6, 3)]):
            p = rng.integers(0, cfg.vocab_size, (plen,), np.int32)
            reqs.append((router.submit(p, gen, seed=i), p, gen, i))
        for t, p, gen, i in reqs:
            comp = t.result(timeout=120)
            iso = generate(model, params, p[None], gen, driver="fused",
                           seed=i)
            np.testing.assert_array_equal(comp.tokens, iso["gen"][0])


# -- HTTP surface -----------------------------------------------------------

@pytest.fixture(scope="module")
def http_server(setup):
    cfg, model, params = setup
    router = Router(_engines(model, params, 2), queue_depth=4)
    server, shutdown = serve_in_thread(router)
    yield cfg, model, params, server, router
    shutdown()


def _post(port, body, timeout=120):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    conn.request("POST", "/v1/generate", json.dumps(body),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    status, data = resp.status, resp.read()
    conn.close()
    return status, data


def _get(port, path, timeout=30):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    conn.request("GET", path)
    resp = conn.getresponse()
    status, data = resp.status, resp.read()
    conn.close()
    return status, data


def test_http_healthz_and_stats(http_server):
    cfg, model, params, server, router = http_server
    status, data = _get(server.port, "/healthz")
    body = json.loads(data)
    assert status == 200 and body["status"] == "ok"
    assert body["live_replicas"] == 2 and body["queue_depth"] >= 0
    status, data = _get(server.port, "/stats")
    stats = json.loads(data)
    assert status == 200 and len(stats["replicas"]) == 2
    assert stats["live_replicas"] == 2
    assert all(r["state"] == "live" and r["error"] is None
               for r in stats["replicas"])


def test_http_generate_parity_and_sampling(http_server):
    """Per-request sampling params ride the wire: greedy and sampled
    requests both match their isolated fused runs token-for-token."""
    cfg, model, params, server, router = http_server
    p = _prompt(cfg, 4, seed=5).tolist()
    status, data = _post(server.port, {"prompt": p, "gen": 5, "seed": 7})
    out = json.loads(data)
    iso = generate(model, params, np.asarray(p, np.int32)[None], 5,
                   driver="fused", seed=7)
    assert status == 200 and out["tokens"] == iso["gen"][0].tolist()
    status, data = _post(server.port, {
        "prompt": p, "gen": 5, "seed": 3, "temperature": 0.9, "top_k": 16})
    out = json.loads(data)
    iso = generate(model, params, np.asarray(p, np.int32)[None], 5,
                   driver="fused", seed=3, temperature=0.9, top_k=16)
    assert status == 200 and out["tokens"] == iso["gen"][0].tolist()


def test_http_stream_sse_parity(http_server):
    """SSE deltas, reassembled in order, are exactly the isolated run's
    tokens, and the terminal ``done`` event repeats the full list."""
    cfg, model, params, server, router = http_server
    p = _prompt(cfg, 4, seed=6).tolist()
    conn = http.client.HTTPConnection("127.0.0.1", server.port, timeout=120)
    conn.request("POST", "/v1/generate", json.dumps(
        {"prompt": p, "gen": 6, "seed": 9, "stream": True}))
    resp = conn.getresponse()
    assert resp.status == 200
    assert resp.getheader("Content-Type") == "text/event-stream"
    raw = resp.read().decode()
    conn.close()
    deltas, done = [], None
    for block in raw.strip().split("\n\n"):
        lines = block.split("\n")
        event = [ln[7:] for ln in lines if ln.startswith("event: ")]
        data = [json.loads(ln[6:]) for ln in lines
                if ln.startswith("data: ")]
        if event and event[0] == "done":
            done = data[0]
        else:
            deltas.extend(data[0]["tokens"])
    iso = generate(model, params, np.asarray(p, np.int32)[None], 6,
                   driver="fused", seed=9)
    assert deltas == done["tokens"] == iso["gen"][0].tolist()


def test_http_bad_request_400(http_server):
    cfg, model, params, server, router = http_server
    status, data = _post(server.port, {"prompt": [1, 2], "gen": 4,
                                       "temperature": -2.0})
    assert status == 400 and "temperature" in json.loads(data)["error"]
    status, data = _post(server.port, {"gen": 4})
    assert status == 400


def test_http_deadline_504_then_recovers(http_server):
    cfg, model, params, server, router = http_server
    p = _prompt(cfg, 3, seed=8).tolist()
    status, data = _post(server.port,
                         {"prompt": p, "gen": 10, "deadline_ms": 0})
    assert status == 504 and "deadline" in json.loads(data)["error"]
    status, data = _post(server.port, {"prompt": p, "gen": 3})
    assert status == 200


def test_http_queue_full_429(setup):
    """With one slot and queue_depth=1, a second request while the first
    is mid-generation gets 429 + Retry-After immediately."""
    cfg, model, params = setup
    router = Router(_engines(model, params, 1, slots=1, max_len=64,
                             chunk_steps=2), queue_depth=1)
    server, shutdown = serve_in_thread(router)
    try:
        p = _prompt(cfg, 3, seed=4).tolist()
        # park a long request without reading its (streaming) response
        sock = socket.create_connection(("127.0.0.1", server.port),
                                        timeout=60)
        body = json.dumps({"prompt": p, "gen": 48, "stream": True}).encode()
        sock.sendall(b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                     b"Content-Length: " + str(len(body)).encode()
                     + b"\r\n\r\n" + body)
        # wait until it is actually outstanding, then expect 429
        for _ in range(200):
            if router.stats()["replicas"][0]["outstanding"] > 0:
                break
            time.sleep(0.02)
        status, data = _post(server.port, {"prompt": p, "gen": 3})
        assert status == 429, (status, data)
        sock.close()
    finally:
        shutdown()


def test_http_disconnect_cancels_request(setup):
    """Dropping the socket mid-stream propagates to Engine.cancel: the
    replica goes fully idle instead of decoding for a dead client."""
    cfg, model, params = setup
    router = Router(_engines(model, params, 1, slots=1, max_len=64,
                             chunk_steps=2), queue_depth=4)
    server, shutdown = serve_in_thread(router)
    try:
        p = _prompt(cfg, 3, seed=4).tolist()
        sock = socket.create_connection(("127.0.0.1", server.port),
                                        timeout=60)
        body = json.dumps({"prompt": p, "gen": 48, "stream": True}).encode()
        sock.sendall(b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                     b"Content-Length: " + str(len(body)).encode()
                     + b"\r\n\r\n" + body)
        buf = b""
        while b"data: " not in buf:      # at least one delta arrived
            buf += sock.recv(4096)
        sock.close()                     # client walks away
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            rep = router.stats()["replicas"][0]
            if rep["outstanding"] == 0 and rep["busy_slots"] == 0:
                break
            time.sleep(0.1)
        rep = router.stats()["replicas"][0]
        assert rep["outstanding"] == 0 and rep["busy_slots"] == 0, rep
    finally:
        shutdown()
