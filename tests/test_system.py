"""System/integration tests: compression API, FedTTD sync, checkpointing,
fault-tolerant loop, elastic planning, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.comm_compress import CommCompressionConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import AdamW, apply_updates, cosine_schedule
from repro.runtime.elastic import plan_mesh, reshard_batch_assignment
from repro.runtime.fault_tolerance import (
    RestartPolicy, StragglerMonitor, TrainingFailure, simulate_failures,
)
from repro.train import fedttd


# ---------------------------------------------------------------------------
# TTCompressor (paper Fig. 1 compress→transmit→reconstruct)
# ---------------------------------------------------------------------------

def test_compressor_roundtrip_error_bound(rng):
    eps = 0.08
    w = (rng.standard_normal((576, 8)) @ rng.standard_normal((8, 64))
         ).astype(np.float32)
    params = {
        "conv": jnp.asarray(w.reshape(3, 3, 64, 64)),
        "norm": jnp.ones((64,), jnp.float32),
    }
    comp = core.TTCompressor(core.CompressionPolicy(
        eps=eps, svd_method="library"))
    compressed, report = comp.compress(params)
    back = comp.decompress(compressed)
    rel = float(
        jnp.linalg.norm(back["conv"] - params["conv"])
        / jnp.linalg.norm(params["conv"])
    )
    assert rel <= eps + 1e-4
    assert report.ratio > 2.0            # low-rank conv compresses well
    # small params pass through untouched
    np.testing.assert_array_equal(np.asarray(back["norm"]),
                                  np.asarray(params["norm"]))


def test_compressor_rejects_full_rank(rng):
    """Random full-rank matrices should be sent raw (ratio-1 guard)."""
    params = {"w": jnp.asarray(rng.standard_normal((96, 96)).astype(np.float32))}
    comp = core.TTCompressor(core.CompressionPolicy(
        eps=0.01, min_size=128, svd_method="library"))
    compressed, report = comp.compress(params)
    kind = list(report.per_param.values())[0][0]
    assert kind == "raw"


# ---------------------------------------------------------------------------
# FedTTD cross-pod sync
# ---------------------------------------------------------------------------

def test_fedttd_sync_converges_to_average(rng):
    cfg = CommCompressionConfig(eps=0.02, max_rank=48, min_size=256)
    base = rng.standard_normal((64, 48)).astype(np.float32)
    # FedTTD precondition (DiLoCo-style): pods START synchronized; only
    # local drift is exchanged thereafter.
    p0 = {"w": jnp.asarray(base)}
    p1 = {"w": jnp.asarray(base.copy())}
    state = fedttd.init_state([p0, p1])
    # drift the pods apart, sync, repeat — params must track the mean
    for _ in range(3):
        d0 = 0.05 * rng.standard_normal((64, 48)).astype(np.float32)
        d1 = 0.05 * rng.standard_normal((64, 48)).astype(np.float32)
        p0 = {"w": p0["w"] + d0}
        p1 = {"w": p1["w"] + d1}
        (p0, p1), state = fedttd.sync([p0, p1], state, cfg)
        np.testing.assert_allclose(
            np.asarray(p0["w"]), np.asarray(p1["w"]), atol=1e-5
        )
    assert state.syncs == 3
    assert state.sent_bytes <= state.raw_bytes  # never worse than dense


def test_fedttd_error_feedback(rng):
    """With error feedback, repeated syncs of a CONSTANT drift must converge
    to the true average despite lossy compression."""
    cfg = CommCompressionConfig(eps=0.3, max_rank=4, min_size=64)
    drift = rng.standard_normal((32, 32)).astype(np.float32)
    p0 = {"w": jnp.zeros((32, 32), jnp.float32)}
    p1 = {"w": jnp.zeros((32, 32), jnp.float32)}
    state = fedttd.init_state([p0, p1])
    p0 = {"w": p0["w"] + drift}
    p1 = {"w": p1["w"] + drift}
    errs = []
    for _ in range(6):
        (p0, p1), state = fedttd.sync([p0, p1], state, cfg)
        errs.append(float(jnp.linalg.norm(p0["w"] - drift)))
        p0 = {"w": p0["w"]}  # no new drift: residuals must flush through
    assert errs[-1] < errs[0] * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {
        "w": jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32)),
        "b16": jnp.asarray(rng.standard_normal((4,)), jnp.bfloat16),
        "step": jnp.asarray(7, jnp.int32),
    }
    mgr.save(7, state, extra={"data_step": 7})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["b16"].dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(restored["b16"], np.float32),
        np.asarray(state["b16"], np.float32),
    )


def test_checkpoint_gc_and_latest(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    s = {"w": jnp.zeros((2,))}
    for step in [1, 5, 9]:
        mgr.save(step, s)
    assert mgr.latest_step() == 9
    dirs = sorted(os.listdir(tmp_path))
    assert "step_000001" not in dirs        # gc'd
    assert {"step_000005", "step_000009"} <= set(dirs)


def test_checkpoint_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    s = {"w": jnp.zeros((2,))}
    mgr.save(3, s)
    # simulate a crash mid-write at step 9: directory without _COMMITTED
    os.makedirs(tmp_path / "step_000009")
    assert mgr.latest_step() == 3


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restart_policy_recovers():
    inject = simulate_failures({5: "node died", 12: "ICI timeout"})
    progress = []

    def loop(start):
        ckpt = start
        for step in range(start, 20):
            inject(step, resume_step=ckpt)
            progress.append(step)
            if step % 4 == 0:
                ckpt = step
        return 20

    final = RestartPolicy(max_restarts=5, backoff_s=0.001).run(
        loop, log=lambda *a: None
    )
    assert final == 20
    assert 19 in progress
    # restarted from checkpoints, so some steps replayed
    assert len(progress) > 20


def test_restart_policy_gives_up():
    def loop(start):
        raise TrainingFailure(0, 0, "always fails")

    with pytest.raises(RuntimeError):
        RestartPolicy(max_restarts=2, backoff_s=0.001).run(
            loop, log=lambda *a: None
        )


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(sigma_k=3.0, min_steps=5)
    flagged = []
    for i in range(30):
        t = 0.1 + 0.001 * (i % 3)
        if i in (20, 25):
            t = 1.0                      # 10x step
        flagged.append(mon.observe(t, host=f"host{i % 4}"))
    assert flagged[20] and flagged[25]
    assert sum(flagged) == 2
    assert mon.cordon_candidates(threshold=2) == ["host0"] or \
        len(mon.cordon_candidates(threshold=1)) >= 1


# ---------------------------------------------------------------------------
# elastic planning
# ---------------------------------------------------------------------------

def test_elastic_plan_keeps_tp():
    p = plan_mesh(512, model_parallel=16)
    assert p.mesh_shape == (32, 16)
    p2 = plan_mesh(480, model_parallel=16, prev_shape=(32, 16))
    assert p2.mesh_shape == (30, 16) and p2.changed
    p3 = plan_mesh(8, model_parallel=16)   # pool smaller than one TP group
    assert p3.mesh_shape[1] <= 8


def test_reshard_batch_assignment():
    a = reshard_batch_assignment(256, 3)
    assert sum(c for _, c in a) == 256
    assert a[0][0] == 0 and a[-1][0] + a[-1][1] == 256


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    d = SyntheticLM(cfg)
    b1 = d.batch_at(5, shard=0, num_shards=2)
    b2 = d.batch_at(5, shard=0, num_shards=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_at(5, shard=1, num_shards=2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_is_learnable():
    """The Markov structure gives cross-entropy below ln(V) for a bigram
    table — sanity that convergence tests can actually converge."""
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=64, seed=7)
    d = SyntheticLM(cfg)
    counts = np.ones((64, 64))
    for step in range(20):
        b = d.batch_at(step)
        np.add.at(counts, (b["tokens"].ravel(), b["labels"].ravel()), 1)
    p = counts / counts.sum(1, keepdims=True)
    b = d.batch_at(100)
    ll = np.log(p[b["tokens"].ravel(), b["labels"].ravel()]).mean()
    assert -ll < np.log(64) - 0.3


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, 10, 100, floor=0.1)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(100))) <= 0.11
