"""TT-native serving: TTLinear equivalence, decode parity, TT checkpoints."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompressionPolicy,
    TTCompressor,
    dequantize_array,
    dequantize_tt,
    is_tt_linear,
    quant_dtype,
    quantize_array,
    quantize_tt,
    quantize_tt_tree,
    select_layer,
    spectral_decay_pytree,
    tt_apply,
    tt_apply_experts,
    tt_leaf_bytes,
    tt_linear_from_tt,
    tt_param_bytes,
    tt_reconstruct,
    ttd,
)
from repro.models import common as model_common


def _decayed(rng, shape, alpha=1.2):
    w = rng.standard_normal(shape).astype(np.float32)
    mat = w.reshape(-1, shape[-1])
    u, s, vt = np.linalg.svd(mat, full_matrices=False)
    target = s[0] * (np.arange(1, s.size + 1.0) ** -alpha)
    return ((u * target) @ vt).reshape(shape)


# ---------------------------------------------------------------------------
# TTLinear: per-layer apply == slice of the dense reconstruction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,in_ndim", [
    ((3, 64, 96), 1),        # mlp-style  (L, D, F)
    ((3, 64, 4, 16), 1),     # wq-style   (L, D, H, K)
    ((3, 4, 16, 64), 2),     # wo-style   (L, H, K, D)
])
def test_tt_linear_matches_reconstruct(rng, shape, in_ndim):
    w = _decayed(rng, shape)
    tt = ttd(w, eps=0.05, dims=shape)
    lin = tt_linear_from_tt(tt, shape, stack=1, in_ndim=in_ndim,
                            dtype=jnp.float32)
    assert lin is not None
    w_rec = np.asarray(tt_reconstruct(tt))
    in_shape = shape[1:1 + in_ndim]
    x = jnp.asarray(rng.standard_normal((5, *in_shape)), jnp.float32)
    for layer in range(shape[0]):
        y = np.asarray(tt_apply(x, select_layer(lin, layer)))
        wl = w_rec[layer].reshape(int(np.prod(in_shape)), -1)
        y_ref = (np.asarray(x).reshape(5, -1) @ wl).reshape(y.shape)
        scale = max(np.abs(y_ref).max(), 1e-6)
        np.testing.assert_allclose(y, y_ref, atol=1e-4 * scale)


def test_tt_linear_traced_layer_select(rng):
    """select_layer under a traced index (the scan path) == concrete."""
    shape = (4, 32, 48)
    w = _decayed(rng, shape)
    lin = tt_linear_from_tt(ttd(w, eps=0.1, dims=shape), shape, 1, 1,
                            dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32)), jnp.float32)

    def one(idx):
        return tt_apply(x, select_layer(lin, idx))

    ys = jax.lax.map(one, jnp.arange(shape[0]))
    for layer in range(shape[0]):
        np.testing.assert_allclose(
            np.asarray(ys[layer]), np.asarray(one(layer)), atol=1e-6
        )


def test_tt_linear_rejects_padded_dims(rng):
    """Dims that aren't a per-axis concatenation → None (reconstruct)."""
    w = _decayed(rng, (4, 32, 48))
    tt = ttd(w.reshape(2, 2, 32, 48), eps=0.1)      # stack axis split in two
    assert tt_linear_from_tt(tt, (5, 32, 48), stack=1, in_ndim=1) is None


def test_select_layer_out_of_range_clamps(rng):
    """Pinned behavior: an out-of-range layer index — traced or concrete —
    CLAMPS to the last layer (mode="clip"), never NaN-fills.  Covers both
    the TTLinear lead gather and the raw-leaf gather in layer_at."""
    shape = (3, 32, 48)
    w = _decayed(rng, shape)
    lin = tt_linear_from_tt(ttd(w, eps=0.1, dims=shape), shape, 1, 1,
                            dtype=jnp.float32)
    raw = jnp.asarray(rng.standard_normal((3, 8)), jnp.float32)
    tree = {"tt": lin, "raw": raw}

    last = model_common.layer_at(tree, 2)
    for idx in (7, jnp.int32(7)):
        over = model_common.layer_at(tree, idx)
        np.testing.assert_array_equal(
            np.asarray(over["tt"].lead), np.asarray(last["tt"].lead)
        )
        np.testing.assert_array_equal(
            np.asarray(over["raw"]), np.asarray(last["raw"])
        )
    # under jit (the traced-scan path) the same clamp applies
    over_jit = jax.jit(lambda i: model_common.layer_at(tree, i))(99)
    np.testing.assert_array_equal(
        np.asarray(over_jit["tt"].lead), np.asarray(last["tt"].lead)
    )
    assert np.isfinite(np.asarray(over_jit["raw"])).all()


# ---------------------------------------------------------------------------
# Expert-bank TTLinear (MoE): batched apply == per-expert dense slices
# ---------------------------------------------------------------------------

def test_tt_linear_expert_bank_matches_reconstruct(rng):
    shape = (3, 4, 32, 48)                          # (L, E, D, F)
    w = _decayed(rng, shape)
    tt = ttd(w, eps=0.05, dims=shape)
    lin = tt_linear_from_tt(tt, shape, stack=2, in_ndim=1,
                            dtype=jnp.float32, experts=1)
    assert lin is not None
    assert lin.experts == 4
    assert lin.lead.shape == (3, 4, lin.cores[0].shape[0])
    w_rec = np.asarray(tt_reconstruct(tt)).reshape(shape)
    x = jnp.asarray(rng.standard_normal((4, 5, 32)), jnp.float32)
    for layer in range(shape[0]):
        sel = select_layer(lin, layer)
        assert sel.lead.shape == (4, lin.cores[0].shape[0])
        y = np.asarray(tt_apply_experts(x, sel))    # (E, 5, F)
        for e in range(shape[1]):
            y_ref = np.asarray(x[e]) @ w_rec[layer, e]
            scale = max(np.abs(y_ref).max(), 1e-6)
            np.testing.assert_allclose(y[e], y_ref, atol=1e-4 * scale)


# ---------------------------------------------------------------------------
# dense_apply dispatch
# ---------------------------------------------------------------------------

def test_dense_apply_raw_matches_einsum(rng):
    x = jnp.asarray(rng.standard_normal((2, 3, 16)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((16, 4, 8)), jnp.bfloat16)
    out = model_common.dense_apply(x, w, in_ndim=1)
    ref = jnp.einsum("bsd,dhk->bshk", x, w)
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=1e-2
    )
    o = jnp.asarray(rng.standard_normal((2, 3, 4, 8)), jnp.bfloat16)
    wo = jnp.asarray(rng.standard_normal((4, 8, 16)), jnp.bfloat16)
    out2 = model_common.dense_apply(o, wo, in_ndim=2)
    ref2 = jnp.einsum("bshk,hkd->bsd", o, wo)
    np.testing.assert_allclose(
        np.asarray(out2, np.float32), np.asarray(ref2, np.float32), atol=1e-1
    )


# ---------------------------------------------------------------------------
# Accounting + conversion plumbing
# ---------------------------------------------------------------------------

def test_tt_param_bytes_skips_non_array_leaves(rng):
    """Pytrees carrying Python scalars (step counters in checkpoint trees)
    must not crash the byte accounting — non-array leaves are skipped."""
    arr = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    tree = {"w": arr, "step": 7, "lr": 1e-3, "done": False}
    assert tt_param_bytes(tree) == arr.size * 4
    # numpy scalars still count (they carry size/dtype)
    tree["np_step"] = np.int32(7)
    assert tt_param_bytes(tree) == arr.size * 4 + 4


def _payload_one(rng):
    params = {"layers": {"mlp": {"w_gate": jnp.asarray(
        _decayed(rng, (3, 64, 96)), jnp.bfloat16)}}}
    comp = TTCompressor(CompressionPolicy(eps=0.1, min_size=1024))
    payload, _ = comp.compress(params)
    return payload


def test_tt_native_params_core_dtype_sentinel(rng):
    """None is the only "unset" sentinel: explicit dtypes are honored even
    when they'd compare falsy/equal-to-default after normalization, and
    None falls back to each leaf's original dtype."""
    payload = _payload_one(rng)

    def tt_leaf(tree):
        leaves = [leaf for leaf in jax.tree.leaves(tree, is_leaf=is_tt_linear)
                  if is_tt_linear(leaf)]
        assert len(leaves) == 1
        return leaves[0]

    default = tt_leaf(model_common.tt_native_params(payload))
    assert all(c.dtype == jnp.bfloat16 for c in default.cores)  # orig dtype
    explicit = tt_leaf(model_common.tt_native_params(
        payload, core_dtype=jnp.float32))
    assert all(c.dtype == jnp.float32 for c in explicit.cores)
    # an explicit dtype equal to the original must take the same branch as
    # any other explicit dtype (the old `or` collapsed this case)
    same = tt_leaf(model_common.tt_native_params(
        payload, core_dtype=jnp.bfloat16))
    assert all(c.dtype == jnp.bfloat16 for c in same.cores)


def test_tt_serve_rules_registry_covers_every_family():
    """Each family registers its own rule set beside its model module."""
    for fam in ("dense", "moe", "vlm", "encdec", "ssm", "hybrid"):
        assert model_common.tt_serve_rules(fam), fam
    union = model_common.tt_serve_rules(None)
    assert len(union) > len(model_common.tt_serve_rules("ssm"))
    # unknown family: no rules, everything reconstructs (no crash)
    assert model_common.tt_serve_rules("no-such-family") == ()


def test_tt_checkpoint_family_guard(rng, tmp_path):
    """A payload saved with a recorded family refuses to serve a different
    arch family; the matching family (or a legacy manifest without one)
    loads normally."""
    from argparse import Namespace
    from types import SimpleNamespace

    from repro.checkpoint.checkpoint import save_tt_payload
    from repro.launch import serve as serve_mod

    payload = _payload_one(rng)
    like = jax.tree.map(
        lambda c: jnp.zeros(c.orig_shape, c.orig_dtype), payload,
        is_leaf=lambda x: hasattr(x, "kind"),
    )
    path = str(tmp_path / "ttck")
    save_tt_payload(path, payload, extra={"eps": 0.1}, family="ssm")
    args = Namespace(tt_checkpoint=path, tt_eps=0.2, tt_alpha=1.0,
                     save_tt_checkpoint=None)

    with pytest.raises(ValueError, match="family"):
        serve_mod._tt_setup(like, args, SimpleNamespace(family="dense"))
    params_tt, loaded, line = serve_mod._tt_setup(
        like, args, SimpleNamespace(family="ssm"))
    assert "weight bytes" in line


# ---------------------------------------------------------------------------
# Quantized TT cores: round-trip bounds, apply parity, byte accounting
# ---------------------------------------------------------------------------

def _tt_linear_one(rng, shape=(3, 64, 96), experts=0, eps=0.05):
    stack = 1 + (1 if experts else 0)
    w = _decayed(rng, shape)
    tt = ttd(w, eps=eps, dims=shape)
    lin = tt_linear_from_tt(tt, shape, stack=stack, in_ndim=1,
                            dtype=jnp.float32, experts=experts)
    assert lin is not None
    return lin


def test_quantize_roundtrip_error_bound(rng):
    """Symmetric round-to-nearest int8: per-element error <= scale/2 =
    amax/(2*127) — the documented bound the module docstring carries."""
    a = jnp.asarray(rng.standard_normal((64, 48)) * 3.0, jnp.float32)
    q, s = quantize_array(a)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_array(q, s)) - np.asarray(a))
    amax = float(np.abs(np.asarray(a)).max())
    assert err.max() <= amax / (2 * 127) + 1e-7
    # per-row (lead-table) scales bound per ROW by that row's amax
    qr, sr = quantize_array(a, axis=-1)
    err_r = np.abs(np.asarray(dequantize_array(qr, sr, axis=-1))
                   - np.asarray(a))
    row_amax = np.abs(np.asarray(a)).max(axis=-1, keepdims=True)
    assert (err_r <= row_amax / (2 * 127) + 1e-7).all()
    # all-zero groups round-trip exactly (scale pinned to 1)
    qz, sz = quantize_array(jnp.zeros((4, 4)))
    assert float(sz) == 1.0
    np.testing.assert_array_equal(np.asarray(dequantize_array(qz, sz)), 0.0)


def test_quantize_tt_roundtrip_and_idempotence(rng):
    """dequantize_tt inverts to the grid; requantizing the dequantized form
    (absmax calibration) is bit-identical — the property the int8
    checkpoint round-trip leans on."""
    lin = _tt_linear_one(rng)
    q = quantize_tt(lin)
    assert q.quantized and not lin.quantized
    assert all(g.dtype == jnp.int8 for g in q.cores)
    assert q.lead.dtype == jnp.int8 and q.lead_scale.shape == (3,)
    wide = dequantize_tt(q)
    assert not wide.quantized
    q2 = quantize_tt(wide)
    for a, b in zip(jax.tree.leaves(q), jax.tree.leaves(q2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # double-quantization is a bug, not a silent no-op
    with pytest.raises(AssertionError):
        quantize_tt(q)


def test_quantized_tt_apply_matches_dequantized(rng):
    """Quantized apply (fused in-kernel dequant) == apply of the explicitly
    dequantized TTLinear — same chain, same order, f32 tolerance."""
    lin = _tt_linear_one(rng)
    q = quantize_tt(lin)
    wide = dequantize_tt(q)
    x = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    for layer in range(3):
        y_q = np.asarray(tt_apply(x, select_layer(q, layer)))
        y_w = np.asarray(tt_apply(x, select_layer(wide, layer)))
        scale = max(np.abs(y_w).max(), 1e-6)
        np.testing.assert_allclose(y_q, y_w, atol=1e-4 * scale)
        # and the quantization itself stays small vs the unquantized apply
        y0 = np.asarray(tt_apply(x, select_layer(lin, layer)))
        assert np.abs(y_q - y0).max() <= 0.05 * max(np.abs(y0).max(), 1.0)


def test_quantized_expert_bank_matches_dequantized(rng):
    """Quantized expert-batched chain == per-expert dequantized applies —
    the (layer, expert)-row lead scales must land on the right rows."""
    lin = _tt_linear_one(rng, shape=(3, 4, 32, 48), experts=1)
    q = quantize_tt(lin)
    assert q.lead_scale.shape == (3, 4)
    wide = dequantize_tt(q)
    x = jnp.asarray(rng.standard_normal((4, 5, 32)), jnp.float32)
    for layer in range(3):
        y_q = np.asarray(tt_apply_experts(x, select_layer(q, layer)))
        y_w = np.asarray(tt_apply_experts(x, select_layer(wide, layer)))
        scale = max(np.abs(y_w).max(), 1e-6)
        np.testing.assert_allclose(y_q, y_w, atol=1e-4 * scale)


def test_quant_dtype_registry():
    assert quant_dtype("int8") == jnp.int8
    with pytest.raises(ValueError, match="int8"):
        quant_dtype("int3")
    with pytest.raises(ValueError, match="calibration"):
        quantize_array(jnp.ones((4, 4)), calib="bogus")
    with pytest.raises(ValueError, match="calibration"):
        quantize_array(jnp.ones((4, 4)), calib="p0")   # 0th pct is invalid


def test_tt_param_bytes_matches_tree_walk(rng):
    """The reported bytes must equal an independent jax.tree byte walk over
    every array hanging off the pytree — scales included.  This is the
    regression for the original bug: tt_param_bytes enumerated lead+cores
    by hand, so the quantization scale arrays escaped the accounting."""
    lin = _tt_linear_one(rng)
    q = quantize_tt(lin)
    tree = {"tt": q, "raw": jnp.asarray(rng.standard_normal((16,)),
                                        jnp.float32)}

    def walk_bytes(t):
        return sum(int(a.size) * a.dtype.itemsize
                   for a in jax.tree.leaves(t)
                   if hasattr(a, "size") and hasattr(a, "dtype"))

    # TTLinear is a registered pytree node: jax.tree.leaves reaches lead,
    # cores, scales, and lead_scale without any hand enumeration
    assert tt_param_bytes(tree) == walk_bytes(tree)
    # quantization shrinks the leaf even while scales ride along
    assert tt_param_bytes({"w": q}) < tt_param_bytes({"w": lin})
    # tt_leaf_bytes agrees with the same walk restricted to the TT leaf
    leaf_b, dense_b = tt_leaf_bytes(tree)
    assert leaf_b == walk_bytes({"w": q})
    assert dense_b == 3 * 64 * 96 * 4              # L * in * out * f32


def test_quantize_tt_tree_only_touches_tt_leaves(rng):
    lin = _tt_linear_one(rng)
    raw = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    tree = quantize_tt_tree({"tt": lin, "raw": raw})
    assert tree["tt"].quantized
    assert tree["raw"].dtype == jnp.float32
    # idempotent at the tree level: already-quantized leaves pass through
    tree2 = quantize_tt_tree(tree)
    assert tree2["tt"] is tree["tt"]


def test_tt_native_params_quant(rng):
    """tt_native_params(quant=...) returns int8 TTLinear leaves; junk quant
    names raise the registry's ValueError before any conversion work."""
    payload = _payload_one(rng)
    tree = model_common.tt_native_params(payload, quant="int8")
    leaves = [leaf for leaf in jax.tree.leaves(tree, is_leaf=is_tt_linear)
              if is_tt_linear(leaf)]
    assert leaves and all(
        leaf.quantized and all(g.dtype == jnp.int8 for g in leaf.cores)
        for leaf in leaves
    )
    with pytest.raises(ValueError, match="int8"):
        model_common.tt_native_params(payload, quant="fp97")


def test_tt_payload_checkpoint_quantized_roundtrip(rng, tmp_path):
    """save(quant="int8") → load → requantize is bit-exact: the loaded
    cores sit on the integer grid, so absmax requantization reproduces the
    saved integer values and scales."""
    from repro.checkpoint.checkpoint import load_tt_payload, save_tt_payload

    params = {"w": jnp.asarray(_decayed(rng, (3, 32, 48)))}
    comp = TTCompressor(CompressionPolicy(eps=0.1, min_size=1024))
    payload, _ = comp.compress(params)
    path = str(tmp_path / "ttq")
    save_tt_payload(path, payload, quant="int8")

    loaded, manifest = load_tt_payload(path, like=params)
    assert manifest["quant"] == "int8"
    cp0 = [c for c in jax.tree.leaves(
        payload, is_leaf=lambda x: hasattr(x, "kind")) if c.kind == "tt"]
    cp1 = [c for c in jax.tree.leaves(
        loaded, is_leaf=lambda x: hasattr(x, "kind")) if c.kind == "tt"]
    assert len(cp0) == len(cp1) == 1
    for g0, g1 in zip(cp0[0].tt.cores, cp1[0].tt.cores):
        q0, s0 = quantize_array(jnp.asarray(g0, jnp.float32))
        q1, s1 = quantize_array(jnp.asarray(g1, jnp.float32))
        np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        # loaded core == dequantized form of the saved one (grid-exact)
        np.testing.assert_array_equal(
            np.asarray(g1), np.asarray(dequantize_array(q0, s0))
        )


def test_tt_setup_quantized_reports_byte_ladder(rng, tmp_path):
    """--weights tt-int8 through _tt_setup: quantized leaves come back and
    the report line carries the dense -> tt -> tt-int8 ladder plus the
    TT-served-leaf reduction the bench lane gates on."""
    from argparse import Namespace
    from types import SimpleNamespace

    from repro.launch import serve as serve_mod

    params = {"layers": {"mlp": {"w_gate": jnp.asarray(
        _decayed(rng, (3, 64, 96)), jnp.bfloat16)}}}
    args = Namespace(weights="tt-int8", quant_calib="absmax",
                     tt_checkpoint=None, tt_eps=0.1, tt_alpha=1.0,
                     save_tt_checkpoint=str(tmp_path / "ck"))
    params_tt, payload, line = serve_mod._tt_setup(
        params, args, SimpleNamespace(family=None, name="test"))
    leaves = [leaf for leaf in jax.tree.leaves(
        params_tt, is_leaf=is_tt_linear) if is_tt_linear(leaf)]
    assert leaves and all(leaf.quantized for leaf in leaves)
    assert "tt-int8" in line and "TT-served leaves" in line
    # the saved checkpoint recorded the quantized form
    import json, os
    with open(os.path.join(str(tmp_path / "ck"), "tt_manifest.json")) as f:
        assert json.load(f)["quant"] == "int8"
    with pytest.raises(ValueError, match="int8"):
        serve_mod._quant_of("tt-fp97")
    assert serve_mod._quant_of("tt") is None


# ---------------------------------------------------------------------------
# End-to-end: TT-native decode == reconstruct-then-serve decode
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tt_native_decode_matches_reconstruct():
    from repro.configs import get_config
    from repro.models.registry import build

    cfg = get_config("gemma3-1b").reduced()
    model = build(cfg)
    params = spectral_decay_pytree(model.init(jax.random.PRNGKey(0)))
    comp = TTCompressor(CompressionPolicy(eps=0.2, min_size=8192))
    payload, report = comp.compress(params)
    assert report.ratio > 1.5

    params_rx = comp.decompress(payload)
    params_tt = model_common.tt_native_params(payload)
    tt_leaves = [
        leaf for leaf in jax.tree.leaves(params_tt, is_leaf=is_tt_linear)
        if is_tt_linear(leaf)
    ]
    assert len(tt_leaves) == 7          # wq wk wv wo w_gate w_up w_down
    assert tt_param_bytes(params_tt) < tt_param_bytes(params_rx)

    rng = np.random.default_rng(0)
    b, plen = 2, 6
    prompts = rng.integers(0, cfg.vocab_size, (b, plen), np.int32)
    decode = jax.jit(model.decode_step)
    c1 = model.init_cache(b, plen)
    c2 = model.init_cache(b, plen)
    for i in range(plen):
        tok = jnp.asarray(prompts[:, i:i + 1])
        l1, c1 = decode(params_rx, c1, tok)
        l2, c2 = decode(params_tt, c2, tok)
    d, scale, _ = model_common.logit_parity(l2, l1)
    # same cores, same contraction order — bf16 rounding only, far inside ε
    assert d <= max(0.05 * scale, 1e-3), (d, scale)

    # prefill/forward path takes the TT-aware scan too
    p1 = model.prefill(params_rx, {"tokens": jnp.asarray(prompts)})
    p2 = model.prefill(params_tt, {"tokens": jnp.asarray(prompts)})
    dp, pscale, _ = model_common.logit_parity(p2, p1)
    assert dp <= max(0.05 * pscale, 1e-3), dp


# ---------------------------------------------------------------------------
# TT payload checkpoint round-trip
# ---------------------------------------------------------------------------

def test_tt_payload_checkpoint_roundtrip(rng, tmp_path):
    from repro.checkpoint.checkpoint import load_tt_payload, save_tt_payload

    params = {
        "w": jnp.asarray(_decayed(rng, (3, 32, 48))),
        "norm": jnp.asarray(rng.standard_normal((8,)), jnp.float32),
        "embed": jnp.asarray(_decayed(rng, (64, 96)), jnp.bfloat16),
    }
    comp = TTCompressor(CompressionPolicy(eps=0.1, min_size=1024))
    payload, _ = comp.compress(params)
    path = str(tmp_path / "ttckpt")
    save_tt_payload(path, payload, extra={"eps": 0.1})

    # overwriting an existing committed payload goes through the .old swap
    save_tt_payload(path, payload, extra={"eps": 0.1})

    loaded, manifest = load_tt_payload(path, like=params)
    assert manifest["extra"]["eps"] == 0.1
    flat0 = jax.tree_util.tree_flatten_with_path(
        payload, is_leaf=lambda x: hasattr(x, "kind"))[0]
    flat1 = jax.tree_util.tree_flatten_with_path(
        loaded, is_leaf=lambda x: hasattr(x, "kind"))[0]
    for (p0, c0), (p1, c1) in zip(flat0, flat1):
        assert p0 == p1
        assert c0.kind == c1.kind
        assert tuple(c0.orig_shape) == tuple(c1.orig_shape)
        assert jnp.dtype(c0.orig_dtype) == jnp.dtype(c1.orig_dtype)
        if c0.kind == "tt":
            assert tuple(c0.tt.ranks) == tuple(c1.tt.ranks)
            assert c0.tt.eps == c1.tt.eps
            for g0, g1 in zip(c0.tt.cores, c1.tt.cores):
                np.testing.assert_array_equal(
                    np.asarray(g0, np.float32), np.asarray(g1, np.float32)
                )
    # reconstruction error is preserved exactly
    rec0 = comp.decompress(payload)
    rec1 = comp.decompress(loaded)
    for a, b in zip(jax.tree.leaves(rec0), jax.tree.leaves(rec1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )
