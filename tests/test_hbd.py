"""Householder bidiagonalization (paper Algorithm 2) invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hbd import (
    bidiagonal_bands,
    house,
    house_mm_update,
    householder_bidiagonalize,
)

SHAPES = [(8, 8), (12, 7), (16, 5), (5, 5), (30, 20), (64, 48), (33, 17)]


@pytest.mark.parametrize("m,n", SHAPES)
def test_reconstruction(rng, m, n):
    a = rng.standard_normal((m, n)).astype(np.float32)
    ub, b, vbt = householder_bidiagonalize(jnp.asarray(a))
    rec = np.asarray(ub) @ np.asarray(b) @ np.asarray(vbt)
    np.testing.assert_allclose(rec, a, atol=5e-5 * np.sqrt(m * n))


@pytest.mark.parametrize("m,n", SHAPES)
def test_orthogonality(rng, m, n):
    a = rng.standard_normal((m, n)).astype(np.float32)
    ub, _, vbt = householder_bidiagonalize(jnp.asarray(a))
    np.testing.assert_allclose(
        np.asarray(ub) @ np.asarray(ub).T, np.eye(m), atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(vbt) @ np.asarray(vbt).T, np.eye(n), atol=2e-5
    )


@pytest.mark.parametrize("m,n", SHAPES)
def test_bidiagonal_structure(rng, m, n):
    a = rng.standard_normal((m, n)).astype(np.float32)
    _, b, _ = householder_bidiagonalize(jnp.asarray(a), compute_uv=False)
    bb = np.asarray(b).copy()
    for i in range(min(m, n)):
        bb[i, i] = 0.0
        if i + 1 < n:
            bb[i, i + 1] = 0.0
    assert np.abs(bb).max() == 0.0


def test_house_matches_paper_eq3(rng):
    """HOUSE output: q = -sign(x1)||x||, v = x + sign(x1)||x|| e1 (masked)."""
    x = rng.standard_normal(10).astype(np.float32)
    mask = np.arange(10) >= 3
    res = house(jnp.asarray(x), jnp.asarray(mask))
    xa = np.where(mask, x, 0.0)
    norm = np.linalg.norm(xa)
    sign = 1.0 if xa[3] >= 0 else -1.0
    assert np.isclose(float(res.q), -sign * norm, rtol=1e-6)
    expected_v = xa.copy()
    expected_v[3] += sign * norm
    np.testing.assert_allclose(np.asarray(res.v), expected_v, rtol=1e-6)


def test_house_mm_update_is_reflection(rng):
    """HOUSE_MM_UPDATE(q, v, A, 0) == H @ A with H = I - 2vv^T/(v^Tv)."""
    m, n = 12, 9
    a = rng.standard_normal((m, n)).astype(np.float32)
    x = rng.standard_normal(m).astype(np.float32)
    mask = np.arange(m) >= 0
    res = house(jnp.asarray(x), jnp.asarray(mask))
    col_mask = np.ones(n, bool)
    out = house_mm_update(
        res.q, res.v, jnp.asarray(a), 0,
        jnp.asarray(mask), jnp.asarray(col_mask),
    )
    v = np.asarray(res.v)
    h = np.eye(m) - 2 * np.outer(v, v) / (v @ v)
    np.testing.assert_allclose(np.asarray(out), h @ a, atol=1e-4)


def test_zero_column_is_identity():
    """HOUSE on a zero vector must produce H = I (beta guard)."""
    m, n = 6, 4
    a = np.ones((m, n), np.float32)
    x = np.zeros(m, np.float32)
    mask = np.ones(m, bool)
    res = house(jnp.asarray(x), jnp.asarray(mask))
    out = house_mm_update(
        res.q, res.v, jnp.asarray(a), 0,
        jnp.asarray(mask), jnp.asarray(np.ones(n, bool)),
    )
    np.testing.assert_allclose(np.asarray(out), a)


def test_bands_roundtrip(rng):
    a = rng.standard_normal((10, 6)).astype(np.float32)
    _, b, _ = householder_bidiagonalize(jnp.asarray(a), compute_uv=False)
    d, e = bidiagonal_bands(b)
    assert d.shape == (6,) and e.shape == (5,)
    bn = np.asarray(b)[:6, :6]
    np.testing.assert_allclose(np.diag(bn), np.asarray(d))
    np.testing.assert_allclose(np.diag(bn, 1), np.asarray(e))
