"""§Perf optimization flags must not change model semantics.

Each opt_* knob is a schedule/layout/precision change; this compares loss
and gradients on a reduced config with every knob ON vs the paper-faithful
defaults.  (bf16 knobs get a looser tolerance: they change rounding, not
math.)
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import build
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.steps import TrainState, make_train_step

ARCHS = ["qwen1.5-0.5b", "dbrx-132b", "seamless-m4t-large-v2",
         "gemma3-1b", "mamba2-1.3b"]

STRUCTURAL = ["pad_vocab", "attn_remat", "causal_unroll", "batch_pin",
              "moe_ep", "moe_tp", "moe_a2a"]


def _loss_and_grad(cfg, seed=0):
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    b, s = 2, 64
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.frontend:
        batch["frames" if cfg.frontend == "frames" else "patches"] = (
            jnp.asarray(rng.standard_normal(
                (b, cfg.frontend_len, cfg.d_model)), jnp.float32))

    def loss(p):
        l, _ = model.loss_fn(p, batch)
        return l

    l, g = jax.value_and_grad(loss)(params)
    return float(l), g, params


@pytest.mark.parametrize("arch", ARCHS)
def test_structural_opts_preserve_loss(arch):
    base_cfg = get_config(arch).reduced()
    l0, g0, p0 = _loss_and_grad(base_cfg)

    opt_cfg = base_cfg.with_opts(STRUCTURAL)
    l1, g1, p1 = _loss_and_grad(opt_cfg)

    # pad_vocab changes embed shape; compare loss (same init seed means the
    # non-pad rows coincide only when no padding happened — compare loss
    # within a small tolerance when vocab is already a multiple of 256,
    # otherwise assert finiteness + close loss magnitude).
    assert np.isfinite(l1)
    if base_cfg.vocab_size == opt_cfg.padded_vocab_size:
        np.testing.assert_allclose(l0, l1, rtol=2e-3)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=5e-2, atol=5e-3)
    else:
        # padded table: rows beyond vocab must receive (near-)zero gradient
        assert abs(l1 - l0) / max(abs(l0), 1e-9) < 0.05


def test_pad_vocab_masks_padding_logits():
    cfg = dataclasses.replace(
        get_config("seamless-m4t-large-v2").reduced(), vocab_size=500,
    ).with_opts(["pad_vocab"])
    assert cfg.padded_vocab_size == 512
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 500, (2, 16)), jnp.int32),
        "frames": jnp.asarray(
            rng.standard_normal((2, cfg.frontend_len, cfg.d_model)),
            jnp.float32),
    }
    logits = model.prefill(params, batch)
    assert logits.shape[-1] == 512
    # padding columns can never win an argmax / contribute to CE
    assert float(jnp.max(logits[..., 500:])) < -1e29


def test_opts_train_step_runs():
    cfg = get_config("qwen1.5-0.5b").reduced().with_opts(
        ["attn_remat", "causal_unroll", "batch_pin", "pad_vocab"])
    model = build(cfg)
    opt = AdamW(learning_rate=cosine_schedule(1e-3, 1, 4))
    step = jax.jit(make_train_step(model, opt, batch_axes=()),
                   donate_argnums=(0,))
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=opt.init(params))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 500, (2, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 500, (2, 64)), jnp.int32),
    }
    losses = []
    for _ in range(3):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]
